//! Durable segment store: WAL + on-disk columnar segments, crash
//! recovery, and background compaction.
//!
//! Without this module a [`SegmentedStorage`] lives purely in memory: a
//! restart loses every ingested event and forces a full replay. The
//! `persist` subsystem gives the store a disk footprint with exactly the
//! write amplification its in-memory life cycle already implies:
//!
//! * **Appends** into the active segment are recorded in a write-ahead
//!   log ([`wal`]) *before* they are acknowledged — an `Ok` from
//!   `append` means the event survives a process kill.
//! * **Seals** freeze the active segment into an immutable on-disk
//!   columnar segment file ([`format`]) — the same SoA column layout the
//!   in-memory segment uses — then atomically replace the manifest and
//!   reset the WAL. Sealed files are never modified, only replaced
//!   wholesale by compaction.
//! * **Compaction** merges sealed segment files into one, either
//!   synchronously ([`SegmentedStorage::compact`]) or on a background
//!   [`Compactor`] thread that merges off the write path and atomically
//!   publishes the compacted generation through a
//!   [`crate::graph::SnapshotCell`] (tmp-file + rename, so a crash
//!   leaves either the old or the new generation on disk).
//! * **Recovery** ([`recover`]) rebuilds a store from the manifest +
//!   segment files + WAL tail: exactly the acknowledged prefix comes
//!   back, at a generation no lower than any acknowledged one. Torn WAL
//!   tails (crash mid-write of an unacknowledged record) are dropped;
//!   corrupt records and segment/manifest checksum mismatches surface
//!   as typed [`TgmError::Persist`] errors.
//!
//! ## Directory layout
//!
//! ```text
//! <dir>/MANIFEST         store metadata + live segment list (atomic replace)
//! <dir>/wal.log          active segment's write-ahead log
//! <dir>/static.tgm       write-once static node-feature matrix (if any)
//! <dir>/LOCK             cross-process exclusive lock ([`lock::DirLock`]:
//!                        flock-held while a store is open, auto-released
//!                        by the kernel on process death)
//! <dir>/seg-000001.tgm   immutable sealed segment files
//! <dir>/seg-000002.tgm   (manifest order is oldest-first; numeric order
//! ...                     is allocation order — compaction outputs get
//!                         fresh, higher numbers)
//! ```
//!
//! ## Crash-consistency protocol
//!
//! A seal performs, in order: (1) write + sync the new segment file via
//! a tmp sibling + rename, (2) atomically replace `MANIFEST` (now
//! naming the new segment and expecting WAL epoch `E+1`), (3) reset the
//! WAL to epoch `E+1`. A crash after (2) but before (3) leaves a WAL
//! whose header epoch `E` is one behind the manifest: its events are
//! already inside the sealed file, so recovery discards the stale log
//! instead of double-appending. Compaction renames its pre-synced
//! output into place and then replaces the manifest; the old files are
//! deleted only afterwards, so every intermediate crash state decodes
//! to a complete store.

pub mod compactor;
pub mod format;
pub mod lock;
pub mod mmap;
pub mod wal;

pub use compactor::{plan_tiered_run, CompactionStrategy, Compactor, CompactorConfig};
pub use format::{Manifest, FORMAT_VERSION, SEGMENT_FORMAT_VERSION};
pub use lock::DirLock;
pub use wal::{read_wal, read_wal_tail, WalContents, WalSync, WalTail, WalWriter};

use crate::error::{Result, TgmError};
use crate::graph::events::{EdgeEvent, NodeEvent};
use crate::graph::storage::GraphStorage;
use crate::graph::{SealPolicy, SegmentedStorage};
use crate::obs;
use crate::util::TimeGranularity;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

/// Manifest file name inside a durable store directory.
pub const MANIFEST_FILE: &str = "MANIFEST";
/// WAL file name inside a durable store directory.
pub const WAL_FILE: &str = "wal.log";
/// Write-once static node-feature file (kept out of the manifest so
/// seals and compactions never rewrite the matrix).
pub const STATIC_FILE: &str = "static.tgm";
/// Extension of the background compactor's pre-synced pending outputs
/// (each round writes a uniquely named `compact-N.pending`).
pub(crate) const PENDING_SUFFIX: &str = ".pending";

/// Path of segment file `seq` inside `dir`.
pub fn segment_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("seg-{seq:06}.tgm"))
}

/// True when `dir` already holds a durable store (has a manifest) —
/// callers use this to choose between a fresh
/// [`SegmentedStorage::with_durability`] and [`recover`].
pub fn store_exists(dir: &Path) -> bool {
    dir.join(MANIFEST_FILE).is_file()
}

/// How sealed segment files are opened for serving.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SegmentBacking {
    /// Decode every column into owned heap memory (the default).
    #[default]
    Heap,
    /// Serve columns zero-copy from a read-only mmap of the segment
    /// file: recovery and compaction installs hand out slices over the
    /// kernel page cache instead of decoding heap copies. Byte-identical
    /// to `Heap` (pinned by tests); degrades to `Heap` on platforms
    /// without mmap support.
    Mmap,
}

/// How a [`SegmentedStorage`] persists itself.
#[derive(Debug, Clone)]
pub struct DurabilityPolicy {
    /// Directory holding the manifest, WAL and sealed segment files.
    pub dir: PathBuf,
    /// fsync the WAL on every acknowledged append. Off (the default),
    /// appends are flushed to the OS — they survive a process kill but
    /// not a power loss — at a fraction of the cost; the
    /// `ablation.persist` bench quantifies both.
    pub fsync_appends: bool,
    /// Batch WAL fsyncs behind a leader-follower commit window instead
    /// of syncing per record (only meaningful with `fsync_appends`; see
    /// [`crate::persist::wal`]). Power-loss durability then lands at
    /// [`SegmentedStorage::sync_wal`] / the serving layer's per-chunk
    /// barrier rather than per append.
    pub group_commit: bool,
    /// Backing for sealed segment files on recovery and compaction
    /// install.
    pub backing: SegmentBacking,
}

impl DurabilityPolicy {
    /// Policy over `dir` with flush-only (no-fsync) appends and
    /// heap-decoded segments.
    pub fn new(dir: impl Into<PathBuf>) -> DurabilityPolicy {
        DurabilityPolicy {
            dir: dir.into(),
            fsync_appends: false,
            group_commit: false,
            backing: SegmentBacking::default(),
        }
    }

    /// fsync every acknowledged append (power-loss safety).
    pub fn with_fsync(mut self) -> DurabilityPolicy {
        self.fsync_appends = true;
        self
    }

    /// fsync in leader-follower groups: appends buffer, and one fsync
    /// per [`SegmentedStorage::sync_wal`] barrier (or ingest chunk, at
    /// the serving layer) covers everything appended since the last one.
    /// Implies `with_fsync`-grade durability at each barrier at a
    /// fraction of the per-append cost (`ablation.persist` quantifies
    /// it).
    #[deprecated(
        note = "use `ServingConfig::group_commit` at the serving layer, or set the \
                `fsync_appends`/`group_commit` fields directly"
    )]
    pub fn with_group_commit(mut self) -> DurabilityPolicy {
        self.fsync_appends = true;
        self.group_commit = true;
        self
    }

    /// Serve sealed segment files via mmap (zero-copy recovery and
    /// compaction installs).
    #[deprecated(
        note = "use `ServingConfig::mmap` at the serving layer, or \
                `with_backing(SegmentBacking::Mmap)`"
    )]
    pub fn with_mmap(mut self) -> DurabilityPolicy {
        self.backing = SegmentBacking::Mmap;
        self
    }

    /// Set the sealed-segment backing explicitly.
    pub fn with_backing(mut self, backing: SegmentBacking) -> DurabilityPolicy {
        self.backing = backing;
        self
    }
}

/// Store metadata a durable operation records in the manifest (borrowed
/// from the owning [`SegmentedStorage`] at call time).
pub(crate) struct StoreMeta<'a> {
    pub num_nodes: usize,
    pub fixed_granularity: Option<TimeGranularity>,
    pub static_feat_dim: usize,
    pub static_feats: &'a [f32],
    /// Generation the manifest should record (the post-operation value).
    pub generation: u64,
}

impl StoreMeta<'_> {
    fn manifest(
        &self,
        wal_epoch: u64,
        next_seq: u64,
        segments: Vec<u64>,
        wal_records: u64,
    ) -> Manifest {
        Manifest {
            num_nodes: self.num_nodes,
            fixed_granularity: self.fixed_granularity,
            static_feat_dim: self.static_feat_dim,
            generation: self.generation,
            wal_epoch,
            next_seq,
            segments,
            wal_records,
        }
    }
}

/// Disk-side state of one durable [`SegmentedStorage`] (held inside the
/// store; every mutation of the store calls back into this).
pub(crate) struct Durability {
    policy: DurabilityPolicy,
    wal: WalWriter,
    wal_epoch: u64,
    next_seq: u64,
    /// Live segment sequence numbers, parallel to the store's sealed
    /// stack (oldest first).
    seqs: Vec<u64>,
    /// Acknowledged records in the current WAL epoch. Written into every
    /// manifest (see [`Manifest::wal_records`]) so recovery and tailing
    /// replicas can anchor exact generations; resets with the WAL on
    /// seal.
    wal_records: u64,
    /// Group-commit barrier handle when the policy asked for it.
    sync: Option<WalSync>,
    /// Held for the lifetime of the store: fences a second process (or
    /// a second in-process store) off this directory. The kernel
    /// releases it on process death, so a crashed holder never wedges
    /// recovery.
    _lock: DirLock,
    /// Set when a durable operation failed mid-protocol: the in-memory
    /// store may no longer match the disk, so further durable writes
    /// would be falsely acknowledged. Every operation errors until the
    /// operator recovers from disk.
    poisoned: Option<String>,
}

impl Durability {
    /// Initialize a fresh durable directory (manifest + static-feature
    /// file + empty WAL) under an exclusive [`DirLock`]. Refuses to
    /// clobber an existing store.
    pub(crate) fn init(policy: DurabilityPolicy, meta: &StoreMeta<'_>) -> Result<Durability> {
        std::fs::create_dir_all(&policy.dir)?;
        // Lock before looking at the manifest: two processes racing
        // init on one empty directory must serialize on the flock, or
        // both could pass the exists() check and the loser would reset
        // the winner's store.
        let dir_lock = DirLock::acquire(&policy.dir)?;
        let man_path = policy.dir.join(MANIFEST_FILE);
        if man_path.exists() {
            return Err(TgmError::Persist(format!(
                "{} already holds a durable store; use persist::recover to reopen it",
                policy.dir.display()
            )));
        }
        if meta.static_feat_dim > 0 {
            format::write_static(
                &policy.dir.join(STATIC_FILE),
                meta.static_feat_dim,
                meta.static_feats,
            )?;
        }
        format::write_manifest(&man_path, &meta.manifest(1, 1, Vec::new(), 0))?;
        let mut wal = WalWriter::create(&policy.dir.join(WAL_FILE), 1, policy.fsync_appends)?;
        let sync = policy.group_commit.then(|| wal.enable_group_commit());
        Ok(Durability {
            policy,
            wal,
            wal_epoch: 1,
            next_seq: 1,
            seqs: Vec::new(),
            wal_records: 0,
            sync,
            _lock: dir_lock,
            poisoned: None,
        })
    }

    /// Re-attach to a recovered store: keep the manifest's bookkeeping
    /// and start a fresh WAL at the manifest's epoch. The new log is
    /// **deferred** — it accumulates at the tmp sibling while recovery
    /// replays the surviving tail through the normal append path, and
    /// only [`Durability::commit_wal`] renames it over the original, so
    /// a crash mid-replay still finds the old (complete) log intact.
    fn attach_recovered(
        policy: DurabilityPolicy,
        man: &Manifest,
        dir_lock: DirLock,
    ) -> Result<Durability> {
        sweep_pending_files(&policy.dir);
        // Replay records with fsync off even under `with_fsync`: the
        // original log remains the durable copy until commit (which
        // syncs the rewrite once), so per-record fsyncs would buy
        // nothing and cost one disk round-trip per replayed event.
        // `commit_wal` restores the policy for live appends.
        let wal = WalWriter::create_deferred(&policy.dir.join(WAL_FILE), man.wal_epoch, false)?;
        Ok(Durability {
            policy,
            wal,
            wal_epoch: man.wal_epoch,
            next_seq: man.next_seq,
            seqs: man.segments.clone(),
            // Replay re-records every surviving tail event through
            // `record_edge`/`record_node`, so the counter rebuilds
            // itself to the replayed count.
            wal_records: 0,
            sync: None,
            _lock: dir_lock,
            poisoned: None,
        })
    }

    /// Fail every durable operation until recovery (see
    /// [`Durability::poisoned`]).
    pub(crate) fn poison(&mut self, why: impl Into<String>) {
        if self.poisoned.is_none() {
            self.poisoned = Some(why.into());
        }
    }

    /// True once a durable operation has failed mid-protocol.
    pub(crate) fn is_poisoned(&self) -> bool {
        self.poisoned.is_some()
    }

    fn check_poisoned(&self) -> Result<()> {
        match &self.poisoned {
            Some(why) => Err(TgmError::Persist(format!(
                "durable store is poisoned ({why}); reopen it with persist::recover"
            ))),
            None => Ok(()),
        }
    }

    /// Publish a deferred (recovery-time) WAL at its real path and
    /// restore the store's append-durability policy — per-record fsync,
    /// group commit, or flush-only (replay ran with fsync off — see
    /// [`Durability::attach_recovered`]).
    pub(crate) fn commit_wal(&mut self) -> Result<()> {
        self.wal.commit()?;
        if self.policy.group_commit {
            self.sync = Some(self.wal.enable_group_commit());
        } else {
            self.wal.set_fsync(self.policy.fsync_appends);
        }
        Ok(())
    }

    /// The group-commit barrier handle, when the policy enables it.
    pub(crate) fn wal_sync(&self) -> Option<WalSync> {
        self.sync.clone()
    }

    /// Group-commit barrier: make everything appended so far power-loss
    /// durable. A failed barrier poisons the store (the fsync outcome
    /// of buffered records is unknown, so later acknowledgments would
    /// be unsound).
    pub(crate) fn sync_wal(&mut self) -> Result<()> {
        self.check_poisoned()?;
        let Some(sync) = &self.sync else { return Ok(()) };
        let res = sync.barrier();
        if res.is_err() {
            self.poison("a group-commit fsync failed");
        }
        res
    }

    /// Backing requested for sealed segment files.
    pub(crate) fn backing(&self) -> SegmentBacking {
        self.policy.backing
    }

    /// Re-persist manifest-level metadata (and the static-feature file)
    /// after a post-`with_durability` builder call changed it. The
    /// segment list, WAL epoch and sequence allocation are untouched.
    pub(crate) fn refresh_metadata(&mut self, meta: &StoreMeta<'_>) -> Result<()> {
        self.check_poisoned()?;
        if meta.static_feat_dim > 0 {
            format::write_static(
                &self.dir().join(STATIC_FILE),
                meta.static_feat_dim,
                meta.static_feats,
            )?;
        }
        let man =
            meta.manifest(self.wal_epoch, self.next_seq, self.seqs.clone(), self.wal_records);
        format::write_manifest(&self.dir().join(MANIFEST_FILE), &man)?;
        Ok(())
    }

    /// The backing directory.
    pub(crate) fn dir(&self) -> &Path {
        &self.policy.dir
    }

    /// Durably record one edge append (called *before* the in-memory
    /// push; an error here means the append is not acknowledged). A
    /// failed write may leave partial record bytes in the log, after
    /// which appending anything else would bury acknowledged records
    /// behind garbage — so a WAL IO error poisons the store like a
    /// failed seal does.
    pub(crate) fn record_edge(&mut self, e: &EdgeEvent) -> Result<()> {
        self.check_poisoned()?;
        let res = self.wal.append_edge(e);
        match &res {
            Ok(()) => self.wal_records += 1,
            Err(_) => {
                self.poison("a WAL append failed mid-record (the log tail may be partial)")
            }
        }
        res
    }

    /// Durably record one node-event append (same poisoning contract as
    /// [`Durability::record_edge`]).
    pub(crate) fn record_node(&mut self, e: &NodeEvent) -> Result<()> {
        self.check_poisoned()?;
        let res = self.wal.append_node(e);
        match &res {
            Ok(()) => self.wal_records += 1,
            Err(_) => {
                self.poison("a WAL append failed mid-record (the log tail may be partial)")
            }
        }
        res
    }

    /// Make a seal durable: segment file, then manifest, then WAL reset
    /// (see the module-level crash-consistency protocol). Returns the
    /// sealed file's path so mmap-backed stores can reopen it zero-copy.
    pub(crate) fn persist_seal(
        &mut self,
        seg: &GraphStorage,
        meta: &StoreMeta<'_>,
    ) -> Result<PathBuf> {
        self.check_poisoned()?;
        let start = Instant::now();
        let mut span = obs::span("persist", "seal");
        let seq = self.next_seq;
        let path = segment_path(self.dir(), seq);
        format::write_segment(&path, seg)?;
        let mut seqs = self.seqs.clone();
        seqs.push(seq);
        // The manifest describes the post-seal epoch, whose WAL starts
        // empty — its record count is 0 regardless of how many appends
        // the sealing epoch absorbed.
        let man = meta.manifest(self.wal_epoch + 1, seq + 1, seqs.clone(), 0);
        format::write_manifest(&self.dir().join(MANIFEST_FILE), &man)?;
        self.wal.reset(self.wal_epoch + 1)?;
        self.wal_epoch += 1;
        self.next_seq = seq + 1;
        self.seqs = seqs;
        self.wal_records = 0;
        let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        span.set_detail(format!("seq={seq} bytes={bytes}"));
        let r = obs::registry();
        r.histogram("tgm_seal_duration_us", &[])
            .record_us(start.elapsed().as_micros().min(u64::MAX as u128) as u64);
        r.counter("tgm_seal_bytes_total", &[]).add(bytes);
        Ok(path)
    }

    /// Make a compaction durable: move the merged segment into place
    /// (either renaming a pre-synced `prewritten` file — the background
    /// compactor's path — or encoding + writing it here), replace the
    /// manifest, then delete the files it superseded. The replaced run
    /// is `replaced` segments starting at stack offset `start` (tiered
    /// compaction merges mid-stack runs; full compaction passes 0). The
    /// WAL is untouched: compaction never involves the active segment.
    /// Returns the merged file's path for mmap-backed reopening.
    pub(crate) fn persist_compaction(
        &mut self,
        merged: &GraphStorage,
        start: usize,
        replaced: usize,
        prewritten: Option<&Path>,
        meta: &StoreMeta<'_>,
    ) -> Result<PathBuf> {
        self.check_poisoned()?;
        let began = Instant::now();
        let mut span = obs::span("persist", "compaction");
        let seq = self.next_seq;
        let path = segment_path(self.dir(), seq);
        match prewritten {
            Some(tmp) => {
                std::fs::rename(tmp, &path)?;
                format::sync_parent_dir(&path)?;
            }
            None => format::write_segment(&path, merged)?,
        }
        let old: Vec<u64> = self.seqs[start..start + replaced].to_vec();
        let mut seqs = Vec::with_capacity(self.seqs.len() - replaced + 1);
        seqs.extend_from_slice(&self.seqs[..start]);
        seqs.push(seq);
        seqs.extend_from_slice(&self.seqs[start + replaced..]);
        // Written mid-epoch: `meta.generation` already counts this
        // epoch's acknowledged appends, so the manifest records how many
        // (`wal_records`) — the anchor that lets recovery and replicas
        // reconstruct exact generations instead of lower bounds.
        let man = meta.manifest(self.wal_epoch, seq + 1, seqs.clone(), self.wal_records);
        format::write_manifest(&self.dir().join(MANIFEST_FILE), &man)?;
        self.next_seq = seq + 1;
        self.seqs = seqs;
        for s in old {
            // Best-effort: an undeleted superseded file is unreferenced
            // by the manifest and gets swept on the next recovery.
            let _ = std::fs::remove_file(segment_path(self.dir(), s));
        }
        let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        span.set_detail(format!("seq={seq} replaced={replaced} bytes={bytes}"));
        let r = obs::registry();
        r.counter("tgm_compactions_total", &[]).inc();
        r.histogram("tgm_compaction_duration_us", &[])
            .record_us(began.elapsed().as_micros().min(u64::MAX as u128) as u64);
        r.counter("tgm_compaction_bytes_total", &[]).add(bytes);
        Ok(path)
    }
}

/// What one [`recover_with_report`] run found on disk.
#[derive(Debug, Clone, Default)]
pub struct RecoveryReport {
    /// Sealed segment files reopened.
    pub sealed_segments: usize,
    /// WAL records replayed into the active segment.
    pub replayed_events: usize,
    /// True when a torn trailing record was dropped from the WAL.
    pub torn_tail: bool,
    /// Bytes dropped past the last complete WAL record. A genuine
    /// crash can only tear the final in-flight record, so a value much
    /// larger than one record suggests a corrupted length prefix
    /// mid-file — worth alerting on (see
    /// [`crate::persist::wal::WalContents::dropped_bytes`]).
    pub dropped_bytes: usize,
    /// True when a stale pre-seal WAL (epoch one behind the manifest)
    /// was discarded — its events are inside the last sealed segment.
    pub stale_wal_discarded: bool,
}

/// Rebuild a [`SegmentedStorage`] from a durable directory: sealed
/// segments from the manifest's files, the active tail from the WAL.
///
/// * The recovered store holds **exactly the acknowledged prefix**: all
///   sealed events plus every WAL record that was completely written.
///   A torn trailing record (killed mid-write, never acknowledged) is
///   dropped; a checksum-failing complete record or segment file is a
///   typed [`TgmError::Persist`].
/// * The store resumes at **exactly** the last acknowledged pre-crash
///   generation: the manifest anchors the epoch-start generation (its
///   recorded generation minus [`Manifest::wal_records`]) and each
///   replayed WAL record re-advances it by one — the same arithmetic a
///   tailing replica uses (see [`crate::replica`]). Republished
///   snapshots are therefore never mistaken for stale ones.
/// * `seal` is the recovered store's go-forward policy (it is not
///   persisted; ingestion policy belongs to the process, not the data).
///   Replay bypasses its admission checks — acknowledged data always
///   reopens — and any seal the tail warrants applies afterwards.
pub fn recover(seal: SealPolicy, policy: DurabilityPolicy) -> Result<SegmentedStorage> {
    recover_with_report(seal, policy).map(|(store, _)| store)
}

/// [`recover`], also returning what was found on disk (torn-tail and
/// stale-WAL diagnostics an operator can alert on).
pub fn recover_with_report(
    seal: SealPolicy,
    policy: DurabilityPolicy,
) -> Result<(SegmentedStorage, RecoveryReport)> {
    // The lock comes first: it fences a live writer (this process or
    // another) off the directory before any file is read or swept.
    let mut span = obs::span("persist", "recovery")
        .with_detail(policy.dir.display().to_string());
    let dir_lock = DirLock::acquire(&policy.dir)?;
    let man = format::read_manifest(&policy.dir.join(MANIFEST_FILE))?;
    let mut sealed = Vec::with_capacity(man.segments.len());
    for &seq in &man.segments {
        let seg = format::read_segment_backed(&segment_path(&policy.dir, seq), policy.backing)?;
        if seg.num_nodes() != man.num_nodes {
            return Err(TgmError::Persist(format!(
                "segment {seq} spans {} nodes but the manifest says {}",
                seg.num_nodes(),
                man.num_nodes
            )));
        }
        sealed.push(Arc::new(seg));
    }
    // Sealed segments must cover non-decreasing time spans or the
    // logical-offset layer's concatenation would not be time-sorted.
    for w in sealed.windows(2) {
        if w[1].start_time() < w[0].end_time() {
            return Err(TgmError::Persist(
                "manifest orders segments with overlapping time spans".into(),
            ));
        }
    }

    let mut report = RecoveryReport { sealed_segments: sealed.len(), ..Default::default() };
    let wal_path = policy.dir.join(WAL_FILE);
    let events = if wal_path.exists() {
        let contents = wal::read_wal(&wal_path)?;
        if contents.epoch == man.wal_epoch {
            report.torn_tail = contents.torn_tail;
            report.dropped_bytes = contents.dropped_bytes;
            contents.events
        } else if contents.epoch + 1 == man.wal_epoch {
            // Crash between the manifest replace and the WAL reset: the
            // log's events are already inside the last sealed segment.
            report.stale_wal_discarded = true;
            Vec::new()
        } else {
            return Err(TgmError::Persist(format!(
                "wal epoch {} does not match manifest epoch {} (corrupt store)",
                contents.epoch, man.wal_epoch
            )));
        }
    } else if man.wal_epoch == 1 {
        // Crash between manifest creation and the first WAL write —
        // the only window in which no wal.log can legitimately exist
        // (resets and recovery commits are rename-based).
        Vec::new()
    } else {
        return Err(TgmError::Persist(format!(
            "wal.log is missing but the manifest expects epoch {} — the log was deleted \
             or the directory is incomplete; acknowledged tail events would be silently \
             lost",
            man.wal_epoch
        )));
    };
    report.replayed_events = events.len();

    let static_feats = if man.static_feat_dim > 0 {
        let (dim, feats) = format::read_static(&policy.dir.join(STATIC_FILE))?;
        if dim != man.static_feat_dim || feats.len() != dim * man.num_nodes {
            return Err(TgmError::Persist(format!(
                "static-feature file holds {} values at dim {dim}, manifest expects {} x {}",
                feats.len(),
                man.num_nodes,
                man.static_feat_dim
            )));
        }
        feats
    } else {
        Vec::new()
    };

    sweep_unreferenced_segments(&policy.dir, &man.segments);
    let durability = Durability::attach_recovered(policy, &man, dir_lock)?;
    // The manifest's generation may already count `wal_records` of the
    // current epoch's appends (a mid-epoch compaction or metadata
    // refresh rewrites it); subtracting them anchors the store at the
    // generation *before* any current-epoch append, and the replay below
    // re-advances one per record — landing on exactly the pre-crash
    // generation. Pre-replication manifests decode wal_records as 0,
    // which degrades to the old (lower-bound) behavior.
    let mut store = SegmentedStorage::from_recovered(
        man.num_nodes,
        seal,
        man.fixed_granularity,
        man.static_feat_dim,
        static_feats,
        sealed,
        man.generation.saturating_sub(man.wal_records),
        durability,
    );
    // Replay the acknowledged tail: the (deferred) fresh WAL re-records
    // every event and generations advance one per event exactly as they
    // did pre-crash, but auto-sealing is suppressed — a seal mid-replay
    // would reset the live WAL while the original log is still the only
    // complete copy of the tail. Only after the full replay does the
    // rewritten log replace the original (so recovery itself can crash
    // and re-run), and only then is any seal the tail warrants under
    // the go-forward policy applied through the normal, crash-safe
    // protocol.
    for ev in events {
        store.replay_append(ev)?;
    }
    store.commit_recovered_wal()?;
    store.seal_if_due()?;
    span.set_detail(format!(
        "segments={} replayed={} torn_tail={} dropped_bytes={} stale_wal={}",
        report.sealed_segments,
        report.replayed_events,
        report.torn_tail,
        report.dropped_bytes,
        report.stale_wal_discarded
    ));
    drop(span);
    let r = obs::registry();
    r.counter("tgm_recovery_sealed_segments_total", &[]).add(report.sealed_segments as u64);
    r.counter("tgm_recovery_replayed_events_total", &[]).add(report.replayed_events as u64);
    r.counter("tgm_recovery_dropped_bytes_total", &[]).add(report.dropped_bytes as u64);
    if report.torn_tail {
        r.counter("tgm_recovery_torn_tail_total", &[]).inc();
    }
    if report.stale_wal_discarded {
        r.counter("tgm_recovery_stale_wal_discarded_total", &[]).inc();
    }
    Ok((store, report))
}

/// Delete stale `*.pending` compactor outputs left by a crash (each
/// round uses a unique name, so any survivor is garbage).
fn sweep_pending_files(dir: &Path) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        if entry.file_name().to_string_lossy().ends_with(PENDING_SUFFIX) {
            let _ = std::fs::remove_file(entry.path());
        }
    }
}

/// Delete `seg-*.tgm` files the manifest does not reference (orphans
/// from a crash between a segment write and its manifest replace; on a
/// replica, local copies superseded by primary-side compaction).
pub(crate) fn sweep_unreferenced_segments(dir: &Path, live: &[u64]) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(stem) = name.strip_prefix("seg-").and_then(|s| s.strip_suffix(".tgm")) else {
            continue;
        };
        if let Ok(seq) = stem.parse::<u64>() {
            if !live.contains(&seq) {
                let _ = std::fs::remove_file(entry.path());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeEvent, Event, NodeEvent};

    fn test_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tgm_persist_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn edge(t: i64, src: u32, dst: u32) -> EdgeEvent {
        EdgeEvent { t, src, dst, features: vec![t as f32] }
    }

    fn stream(n: usize) -> Vec<EdgeEvent> {
        (0..n).map(|i| edge(i as i64 * 10, (i % 5) as u32, 5 + (i % 3) as u32)).collect()
    }

    #[test]
    fn durable_store_round_trips_through_recovery() {
        let dir = test_dir("round_trip");
        let mut st = SegmentedStorage::new(8, SealPolicy::by_events(16))
            .with_durability(DurabilityPolicy::new(&dir))
            .unwrap();
        for e in stream(50) {
            st.append_edge(e).unwrap();
        }
        st.append_node_event(NodeEvent { t: 500, node: 1, features: vec![7.0] }).unwrap();
        let gen_before = st.generation();
        let snap_before = st.snapshot().unwrap();
        assert!(st.num_sealed_segments() >= 3, "{}", st.num_sealed_segments());
        assert!(st.pending_edges() > 0, "want a live WAL tail");
        drop(st); // crash: nothing is flushed on drop that wasn't already on disk

        let mut rec =
            recover(SealPolicy::by_events(16), DurabilityPolicy::new(&dir)).unwrap();
        assert!(rec.generation() >= gen_before);
        let snap = rec.snapshot().unwrap();
        assert_eq!(snap.num_edges(), snap_before.num_edges());
        assert_eq!(snap.edge_ts(), snap_before.edge_ts());
        assert_eq!(snap.edge_src(), snap_before.edge_src());
        assert_eq!(snap.edge_dst(), snap_before.edge_dst());
        assert_eq!(snap.edge_feats(), snap_before.edge_feats());
        assert_eq!(snap.num_node_events(), 1);
        assert_eq!(snap.granularity(), snap_before.granularity());
        // The recovered store keeps ingesting durably.
        rec.append_edge(edge(10_000, 0, 5)).unwrap();
        drop(rec);
        let mut again =
            recover(SealPolicy::by_events(16), DurabilityPolicy::new(&dir)).unwrap();
        assert_eq!(again.snapshot().unwrap().num_edges(), snap_before.num_edges() + 1);
    }

    #[test]
    fn wal_only_store_recovers_its_active_tail() {
        let dir = test_dir("tail_only");
        let mut st = SegmentedStorage::new(4, SealPolicy::default())
            .with_durability(DurabilityPolicy::new(&dir))
            .unwrap();
        st.append_edge(edge(5, 0, 1)).unwrap();
        st.append_edge(edge(7, 1, 2)).unwrap();
        drop(st);
        let mut rec = recover(SealPolicy::default(), DurabilityPolicy::new(&dir)).unwrap();
        assert_eq!(rec.num_sealed_segments(), 0);
        assert_eq!(rec.pending_edges(), 2);
        assert_eq!(rec.snapshot().unwrap().edge_ts(), vec![5, 7]);
    }

    #[test]
    fn stale_wal_epoch_is_discarded_not_double_applied() {
        let dir = test_dir("stale_epoch");
        let mut st = SegmentedStorage::new(4, SealPolicy::by_events(2))
            .with_durability(DurabilityPolicy::new(&dir))
            .unwrap();
        st.append_edge(edge(10, 0, 1)).unwrap();
        st.append_edge(edge(20, 1, 2)).unwrap(); // seals; manifest now expects epoch 2
        drop(st);
        // Simulate the crash window between manifest replace and WAL
        // reset: rewrite the WAL at the PRE-seal epoch holding the very
        // events the sealed segment already contains.
        let mut stale = WalWriter::create(&dir.join(WAL_FILE), 1, false).unwrap();
        stale.append(&Event::Edge(edge(10, 0, 1))).unwrap();
        stale.append(&Event::Edge(edge(20, 1, 2))).unwrap();
        drop(stale);
        let mut rec = recover(SealPolicy::by_events(2), DurabilityPolicy::new(&dir)).unwrap();
        assert_eq!(rec.snapshot().unwrap().num_edges(), 2, "stale log must not double-apply");
        drop(rec); // release the directory lock before reopening

        // An epoch from the future is corruption, not a crash artifact.
        let mut future = WalWriter::create(&dir.join(WAL_FILE), 99, false).unwrap();
        future.append(&Event::Edge(edge(30, 0, 1))).unwrap();
        drop(future);
        let err =
            recover(SealPolicy::by_events(2), DurabilityPolicy::new(&dir)).unwrap_err();
        assert!(matches!(err, TgmError::Persist(_)), "{err}");
        assert!(err.to_string().contains("epoch"), "{err}");
    }

    /// Regression: a go-forward seal policy smaller than the WAL tail
    /// used to let the replay auto-seal mid-recovery, resetting the
    /// live WAL while the original log was still the only complete copy
    /// of the tail. The due seal must apply only after the rewritten
    /// log is committed — and must lose nothing.
    #[test]
    fn recovery_with_a_smaller_seal_policy_never_loses_the_tail() {
        let dir = test_dir("shrink_policy");
        let mut st = SegmentedStorage::new(8, SealPolicy::by_events(64))
            .with_durability(DurabilityPolicy::new(&dir))
            .unwrap();
        for e in stream(20) {
            st.append_edge(e).unwrap(); // 20 < 64: everything stays in the WAL
        }
        drop(st);
        let mut rec = recover(SealPolicy::by_events(4), DurabilityPolicy::new(&dir)).unwrap();
        assert_eq!(rec.num_sealed_segments(), 1, "the due seal applies once, post-commit");
        assert_eq!(rec.snapshot().unwrap().num_edges(), 20);
        drop(rec);
        let mut again = recover(SealPolicy::by_events(4), DurabilityPolicy::new(&dir)).unwrap();
        let expect: Vec<i64> = stream(20).iter().map(|e| e.t).collect();
        assert_eq!(again.snapshot().unwrap().edge_ts(), expect);
    }

    /// A WAL can only legitimately be absent before its first creation
    /// (manifest epoch 1); at any later epoch the log held (or may have
    /// held) acknowledged tail events, so its absence is corruption.
    #[test]
    fn missing_wal_at_a_later_epoch_is_corruption_not_an_empty_tail() {
        let dir = test_dir("missing_wal");
        let mut st = SegmentedStorage::new(4, SealPolicy::by_events(2))
            .with_durability(DurabilityPolicy::new(&dir))
            .unwrap();
        st.append_edge(edge(10, 0, 1)).unwrap();
        st.append_edge(edge(20, 1, 2)).unwrap(); // seals -> manifest expects epoch 2
        drop(st);
        std::fs::remove_file(dir.join(WAL_FILE)).unwrap();
        let err = recover(SealPolicy::by_events(2), DurabilityPolicy::new(&dir)).unwrap_err();
        assert!(matches!(err, TgmError::Persist(_)), "{err}");
        assert!(err.to_string().contains("missing"), "{err}");
    }

    #[test]
    fn durability_setup_errors_are_typed() {
        let dir = test_dir("setup_errors");
        // Enabling durability on a non-empty store is refused.
        let mut st = SegmentedStorage::new(4, SealPolicy::default());
        st.append_edge(edge(1, 0, 1)).unwrap();
        let err = st.with_durability(DurabilityPolicy::new(&dir)).unwrap_err();
        assert!(matches!(err, TgmError::Persist(_)), "{err}");

        // A fresh store claims the directory; a second fresh store may
        // not clobber it.
        let _st = SegmentedStorage::new(4, SealPolicy::default())
            .with_durability(DurabilityPolicy::new(&dir))
            .unwrap();
        let err = SegmentedStorage::new(4, SealPolicy::default())
            .with_durability(DurabilityPolicy::new(&dir))
            .unwrap_err();
        assert!(err.to_string().contains("already holds"), "{err}");

        // Recovering a directory that was never a store is typed too.
        let empty = test_dir("never_a_store");
        let err = recover(SealPolicy::default(), DurabilityPolicy::new(&empty)).unwrap_err();
        assert!(matches!(err, TgmError::Persist(_)), "{err}");
    }

    /// Review regression: replay carries events that were admitted (and
    /// acknowledged) pre-crash, so a *tighter* go-forward backpressure
    /// cap must not reject them — acknowledged data must always reopen.
    /// The new cap still applies to fresh appends.
    #[test]
    fn recovery_replays_node_event_tails_past_a_tighter_backpressure_cap() {
        let dir = test_dir("backpressure_replay");
        let mut st = SegmentedStorage::new(
            4,
            SealPolicy::by_events(1000).with_node_event_cap(50),
        )
        .with_durability(DurabilityPolicy::new(&dir))
        .unwrap();
        for t in 0..40 {
            st.append_node_event(NodeEvent { t, node: 0, features: vec![] }).unwrap();
        }
        drop(st);
        let tighter = || SealPolicy::by_events(1000).with_node_event_cap(10);
        let mut rec = recover(tighter(), DurabilityPolicy::new(&dir)).unwrap();
        assert_eq!(rec.pending_node_events(), 40, "every acknowledged event reopens");
        let err = rec
            .append_node_event(NodeEvent { t: 100, node: 1, features: vec![] })
            .unwrap_err();
        assert!(matches!(err, TgmError::Backpressure(_)), "new appends obey the new cap: {err}");
    }

    /// Review regression: a durable seal that fails mid-protocol must
    /// not leave the store acknowledging appends that memory and disk
    /// no longer agree on — it poisons all further durable operations.
    #[test]
    fn failed_durable_seal_poisons_the_store() {
        let dir = test_dir("poison");
        let mut st = SegmentedStorage::new(4, SealPolicy::by_events(2))
            .with_durability(DurabilityPolicy::new(&dir))
            .unwrap();
        for t in 1..=5 {
            st.append_edge(edge(t * 10, 0, 1)).unwrap(); // seals twice, one pending
        }
        assert_eq!(st.num_sealed_segments(), 2);
        // Yank the directory out from under the store. The open WAL fd
        // still accepts the next record (unlinked inode), so the append
        // itself is acknowledged — but the triggered auto-seal's segment
        // write fails, which must NOT retract the acknowledgment
        // (`Ok(false)`: recorded and retained, just not sealed).
        std::fs::remove_dir_all(&dir).unwrap();
        assert!(!st.append_edge(edge(60, 1, 2)).unwrap());
        // The failed seal poisoned the store: later durable operations
        // are refused instead of acknowledged.
        let err = st.append_edge(edge(70, 2, 3)).unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
        let err = st.compact().unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
        // But nothing already ingested vanished from reads: the failed
        // seal's buffer was restored, so snapshots stay complete.
        assert_eq!(st.pending_edges(), 2);
        assert_eq!(st.snapshot().unwrap().edge_ts(), vec![10, 20, 30, 40, 50, 60]);
    }

    #[test]
    fn recovered_generation_is_monotonic_over_acknowledged_appends() {
        let dir = test_dir("generation");
        let mut st = SegmentedStorage::new(8, SealPolicy::by_events(4))
            .with_durability(DurabilityPolicy::new(&dir))
            .unwrap();
        let mut acked = Vec::new();
        for e in stream(11) {
            st.append_edge(e).unwrap();
            acked.push(st.generation());
        }
        let last = *acked.last().unwrap();
        drop(st);
        let rec = recover(SealPolicy::by_events(4), DurabilityPolicy::new(&dir)).unwrap();
        assert!(rec.generation() >= last, "{} < {last}", rec.generation());
    }

    /// The manifest's `wal_records` anchor makes recovery exact, not
    /// just monotonic — including across the tricky case of a
    /// compaction manifest written mid-epoch (whose generation already
    /// counts the epoch's replayed appends).
    #[test]
    fn recovery_resumes_at_the_exact_pre_crash_generation() {
        let dir = test_dir("exact_generation");
        let mut st = SegmentedStorage::new(8, SealPolicy::by_events(4))
            .with_durability(DurabilityPolicy::new(&dir))
            .unwrap();
        for e in stream(10) {
            st.append_edge(e).unwrap(); // seals at 4 and 8; 2 in the WAL
        }
        assert!(st.compact().unwrap(), "mid-epoch compaction writes a manifest with \
                                        wal_records > 0");
        st.append_edge(edge(10_000, 0, 5)).unwrap();
        let last = st.generation();
        drop(st);
        let rec = recover(SealPolicy::by_events(4), DurabilityPolicy::new(&dir)).unwrap();
        assert_eq!(rec.generation(), last);
    }

    #[test]
    fn fixed_granularity_and_static_feats_survive_recovery() {
        let dir = test_dir("meta");
        let mut st = SegmentedStorage::new(4, SealPolicy::by_events(2))
            .with_granularity(TimeGranularity::Hour)
            .with_static_feats(2, vec![0.25; 8])
            .unwrap()
            .with_durability(DurabilityPolicy::new(&dir))
            .unwrap();
        st.append_edge(edge(0, 0, 1)).unwrap();
        st.append_edge(edge(3600, 1, 2)).unwrap();
        drop(st);
        let mut rec = recover(SealPolicy::by_events(2), DurabilityPolicy::new(&dir)).unwrap();
        let snap = rec.snapshot().unwrap();
        assert_eq!(snap.granularity(), TimeGranularity::Hour);
        assert_eq!(snap.static_feat_dim(), 2);
        assert_eq!(snap.static_feats(), &[0.25; 8]);
    }

    /// Review regression: metadata builders called *after*
    /// `with_durability` used to leave the manifest claiming metadata
    /// that was never written, making the directory unrecoverable.
    #[test]
    fn builder_calls_after_with_durability_stay_persisted() {
        let dir = test_dir("late_builders");
        let mut st = SegmentedStorage::new(4, SealPolicy::by_events(2))
            .with_durability(DurabilityPolicy::new(&dir))
            .unwrap()
            .with_granularity(TimeGranularity::Hour)
            .with_static_feats(1, vec![0.5; 4])
            .unwrap();
        st.append_edge(edge(0, 0, 1)).unwrap();
        st.append_edge(edge(3600, 1, 2)).unwrap(); // seals
        drop(st);
        let mut rec = recover(SealPolicy::by_events(2), DurabilityPolicy::new(&dir)).unwrap();
        let snap = rec.snapshot().unwrap();
        assert_eq!(snap.granularity(), TimeGranularity::Hour);
        assert_eq!(snap.static_feat_dim(), 1);
        assert_eq!(snap.static_feats(), &[0.5; 4]);
    }

    #[test]
    fn synchronous_compaction_is_durable() {
        let dir = test_dir("sync_compact");
        let mut st = SegmentedStorage::new(8, SealPolicy::by_events(8))
            .with_durability(DurabilityPolicy::new(&dir))
            .unwrap();
        for e in stream(40) {
            st.append_edge(e).unwrap();
        }
        assert!(st.num_sealed_segments() >= 4);
        let before = st.snapshot().unwrap().edge_ts();
        assert!(st.compact().unwrap());
        assert_eq!(st.num_sealed_segments(), 1);
        drop(st);
        let mut rec = recover(SealPolicy::by_events(8), DurabilityPolicy::new(&dir)).unwrap();
        assert_eq!(rec.num_sealed_segments(), 1);
        assert_eq!(rec.snapshot().unwrap().edge_ts(), before);
        // Superseded files were deleted; only the compacted one remains.
        let seg_files = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().starts_with("seg-"))
            .count();
        assert_eq!(seg_files, 1);
    }

    /// Tentpole (d): two stores — in-process here; flock gives the same
    /// answer across processes — can never hold one durable directory.
    #[test]
    fn directory_lock_fences_concurrent_opens() {
        let dir = test_dir("dir_lock");
        let mut st = SegmentedStorage::new(4, SealPolicy::by_events(4))
            .with_durability(DurabilityPolicy::new(&dir))
            .unwrap();
        st.append_edge(edge(10, 0, 1)).unwrap();
        // A second opener — recovery included — is refused while the
        // first store lives.
        let err = recover(SealPolicy::default(), DurabilityPolicy::new(&dir)).unwrap_err();
        assert!(matches!(err, TgmError::Persist(_)), "{err}");
        assert!(err.to_string().contains("already holds"), "{err}");
        // Dropping the store releases the kernel lock; recovery then
        // proceeds even though the LOCK file is still on disk.
        drop(st);
        assert!(dir.join("LOCK").is_file(), "the lock file is never deleted");
        let mut rec = recover(SealPolicy::default(), DurabilityPolicy::new(&dir)).unwrap();
        assert_eq!(rec.snapshot().unwrap().num_edges(), 1);
    }

    /// Tentpole (c): group commit — appends buffer, one barrier fsync
    /// acknowledges the chunk, and everything barriered survives
    /// recovery.
    #[test]
    fn group_commit_store_round_trips_through_recovery() {
        let dir = test_dir("group_commit");
        let group = |dir: &Path| DurabilityPolicy {
            fsync_appends: true,
            group_commit: true,
            ..DurabilityPolicy::new(dir)
        };
        let mut st = SegmentedStorage::new(8, SealPolicy::by_events(16))
            .with_durability(group(&dir))
            .unwrap();
        for e in stream(40) {
            st.append_edge(e).unwrap();
        }
        st.sync_wal().unwrap();
        let expect = st.snapshot().unwrap().edge_ts();
        drop(st); // kill
        let mut rec = recover(SealPolicy::by_events(16), group(&dir)).unwrap();
        assert_eq!(rec.snapshot().unwrap().edge_ts(), expect);
        // The recovered store keeps group-committing.
        rec.append_edge(edge(10_000, 0, 5)).unwrap();
        rec.sync_wal().unwrap();
        drop(rec);
        let mut again = recover(SealPolicy::by_events(16), group(&dir)).unwrap();
        assert_eq!(again.snapshot().unwrap().num_edges(), expect.len() + 1);
    }

    /// Tentpole (b): an mmap-backed recovery serves byte-identical data
    /// to the heap recovery of the same directory, with the sealed
    /// columns actually mapped.
    #[test]
    fn mmap_backed_recovery_is_byte_identical_to_heap() {
        let dir = test_dir("mmap_recover");
        let mut st = SegmentedStorage::new(8, SealPolicy::by_events(12))
            .with_durability(DurabilityPolicy::new(&dir))
            .unwrap();
        for e in stream(50) {
            st.append_edge(e).unwrap();
        }
        st.append_node_event(NodeEvent { t: 500, node: 1, features: vec![7.0] }).unwrap();
        drop(st);

        let mut heap =
            recover(SealPolicy::by_events(12), DurabilityPolicy::new(&dir)).unwrap();
        let heap_snap = heap.snapshot().unwrap();
        drop(heap); // release the dir lock before the second recovery

        let mut mapped = recover(
            SealPolicy::by_events(12),
            DurabilityPolicy::new(&dir).with_backing(SegmentBacking::Mmap),
        )
        .unwrap();
        let snap = mapped.snapshot().unwrap();
        assert_eq!(snap.edge_ts(), heap_snap.edge_ts());
        assert_eq!(snap.edge_src(), heap_snap.edge_src());
        assert_eq!(snap.edge_dst(), heap_snap.edge_dst());
        assert_eq!(snap.edge_feats(), heap_snap.edge_feats());
        assert_eq!(snap.num_node_events(), heap_snap.num_node_events());
        if crate::persist::mmap::supported() {
            assert!(
                snap.num_mapped_segments() >= snap.num_segments() - 1,
                "sealed segments must serve from the map (only the WAL tail is heap)"
            );
        }
        // The mapped store keeps ingesting, sealing and compacting; new
        // sealed files reopen mapped too.
        for e in stream(30) {
            let shifted = EdgeEvent { t: e.t + 10_000, ..e };
            mapped.append_edge(shifted).unwrap();
        }
        assert!(mapped.compact().unwrap());
        let snap2 = mapped.snapshot().unwrap();
        assert_eq!(snap2.num_edges(), heap_snap.num_edges() + 30);
        if crate::persist::mmap::supported() {
            assert!(snap2.num_mapped_segments() >= 1, "the compacted file reopens mapped");
        }
    }

    #[test]
    fn store_exists_reports_the_manifest() {
        let dir = test_dir("exists");
        assert!(!store_exists(&dir));
        let _st = SegmentedStorage::new(4, SealPolicy::default())
            .with_durability(DurabilityPolicy::new(&dir))
            .unwrap();
        assert!(store_exists(&dir));
    }
}
