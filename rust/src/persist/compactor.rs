//! Background compaction of sealed segments — tiered by default.
//!
//! The synchronous [`SegmentedStorage::compact`] blocks the writer for
//! the whole merge. Because sealed segments are immutable, the merge
//! itself needs no lock — only the final swap does. The [`Compactor`]
//! exploits that split:
//!
//! 1. **Scan** (short lock): if more than [`CompactorConfig::min_sealed`]
//!    sealed segments have piled up, clone their `Arc`s + ids.
//! 2. **Plan + merge + write** (no lock): pick the run to merge —
//!    [`CompactionStrategy::Tiered`] picks size-adjacent runs via
//!    [`plan_tiered_run`], [`CompactionStrategy::Full`] takes the whole
//!    stack — concatenate its columns off the write path; for a durable
//!    store, also encode and write + sync the merged segment to a
//!    uniquely named pending file.
//! 3. **Install + publish** (short lock):
//!    [`SegmentedStorage::install_compacted`] locates the scanned run
//!    by its never-reused ids (appends may have sealed *new* segments
//!    meanwhile — they are untouched; a concurrent compaction that
//!    consumed part of the run makes the lookup fail and the round is
//!    discarded), renames the pending file into place, replaces the
//!    manifest, swaps the in-memory run, and bumps the generation. The
//!    new generation is then published through the [`SnapshotCell`], so
//!    pinned readers keep their old segments (the `Arc`s stay alive)
//!    while new pins observe the compacted layout.
//!
//! ## Why tiered
//!
//! Merging the whole sealed stack every round rewrites every event per
//! round: under sustained ingest of n segments that is O(n) write
//! amplification. Tiering assigns each segment a size *level*
//! (`log_fanout(byte_size)`) and merges only contiguous runs of
//! `>= fanout` same-level segments — each event is rewritten at most
//! once per level, for O(log_fanout n) total amplification, while
//! segment count stays O(fanout x log n). The `ablation.persist` bench
//! measures both at 16/64 sealed segments.
//!
//! Appends never wait on a merge either way: the writer lock is held
//! only for the scan and the O(1) swap + manifest replace.
//! `append_during_background_compaction_…` in `tests/integration.rs`
//! pins this.

use crate::error::Result;
use crate::graph::segment::merge_segments;
use crate::graph::{SegmentedStorage, SnapshotCell};
use crate::obs::{self, Label};
use crate::persist::{format, PENDING_SUFFIX};
use std::io::Write;
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Process-wide counter for pending-output names, so two compactors
/// (e.g. over different tenants sharing a directory tree, or a
/// mistakenly double-attached one) can never rename each other's bytes
/// into place.
static NEXT_PENDING: AtomicU64 = AtomicU64::new(1);

/// Which sealed segments one compaction round merges.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompactionStrategy {
    /// Merge the whole sealed stack into one segment every round —
    /// minimal segment count, O(n) write amplification per round under
    /// sustained ingest.
    Full,
    /// Merge contiguous runs of `>= fanout` segments in the same byte-
    /// size level (see [`plan_tiered_run`]): O(log_fanout n) write
    /// amplification, segment count bounded by
    /// O(fanout x log_fanout n).
    Tiered {
        /// Segments per level before a merge triggers (clamped to
        /// `>= 2`). Larger fanout = fewer, bigger merges and a wider
        /// stack; 4 is a good default.
        fanout: usize,
    },
}

impl Default for CompactionStrategy {
    fn default() -> Self {
        CompactionStrategy::Tiered { fanout: 4 }
    }
}

/// Background-compaction policy.
#[derive(Debug, Clone)]
pub struct CompactorConfig {
    /// Compact once more than this many sealed segments have piled up
    /// (clamped to at least 1 so a compacted store never re-compacts).
    pub min_sealed: usize,
    /// Poll period between scans when there is nothing to do.
    pub interval: Duration,
    /// Run-selection strategy (tiered by default).
    pub strategy: CompactionStrategy,
}

impl Default for CompactorConfig {
    fn default() -> Self {
        CompactorConfig {
            min_sealed: 4,
            interval: Duration::from_millis(20),
            strategy: CompactionStrategy::default(),
        }
    }
}

/// Size level of one segment: `floor(log_fanout(bytes))`. Segments
/// whose byte sizes are within a factor of `fanout` of each other land
/// in the same level and are merge candidates.
fn level_of(bytes: usize, fanout: usize) -> u32 {
    let mut s = bytes.max(1);
    let mut level = 0u32;
    while s >= fanout {
        s /= fanout;
        level += 1;
    }
    level
}

/// Plan one tiered-compaction round over sealed-segment byte sizes
/// (oldest first): the maximal contiguous run of `>= fanout` segments
/// sharing a size level, preferring the **lowest** level (cheapest
/// merge, and the level new seals feed, so it drains first) and the
/// oldest run on ties. `None` when no level has piled up `fanout`
/// adjacent segments — the stack is at its tiering fixpoint.
///
/// Only *adjacent* segments ever merge: sealed segments cover
/// non-decreasing time spans, so a merged run must be contiguous to
/// keep the concatenated columns globally time-sorted.
pub fn plan_tiered_run(sizes: &[usize], fanout: usize) -> Option<Range<usize>> {
    let fanout = fanout.max(2);
    let mut best: Option<(u32, Range<usize>)> = None;
    let mut start = 0usize;
    while start < sizes.len() {
        let level = level_of(sizes[start], fanout);
        let mut end = start + 1;
        while end < sizes.len() && level_of(sizes[end], fanout) == level {
            end += 1;
        }
        if end - start >= fanout
            && best.as_ref().is_none_or(|(best_level, _)| level < *best_level)
        {
            best = Some((level, start..end));
        }
        start = end;
    }
    best.map(|(_, run)| run)
}

/// Handle over one background compaction thread. Dropping it stops the
/// thread (joining it); [`Compactor::stop`] does the same explicitly.
pub struct Compactor {
    stop: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<()>>,
    compactions: Arc<AtomicUsize>,
    last_error: Arc<Mutex<Option<String>>>,
    /// `tgm_compactor_error{compactor}`: 1 while the most recent round
    /// failed, 0 once a later round succeeds (mirrors
    /// [`Compactor::last_error`] as a scrapeable registry series).
    error_gauge: obs::Gauge,
    /// `tgm_compactor_errors_total{compactor}` (monotonic).
    errors_total: obs::Counter,
}

impl Compactor {
    /// Spawn a compactor over a shared store, publishing each compacted
    /// generation through `cell` (pass the same cell the serving layer
    /// pins from; the published snapshot includes the frozen active
    /// tail, exactly like any writer-side publish).
    pub fn spawn(
        store: Arc<Mutex<SegmentedStorage>>,
        cell: SnapshotCell,
        cfg: CompactorConfig,
    ) -> Compactor {
        let stop = Arc::new(AtomicBool::new(false));
        let compactions = Arc::new(AtomicUsize::new(0));
        let last_error = Arc::new(Mutex::new(None));
        // Per-instance registry series: concurrent compactors (one per
        // tenant, or tests running in parallel) never share a gauge.
        static COMPACTOR_SEQ: AtomicU64 = AtomicU64::new(0);
        let compactor_id =
            Label::from(COMPACTOR_SEQ.fetch_add(1, Ordering::Relaxed).to_string());
        let registry = obs::registry();
        let error_gauge =
            registry.gauge("tgm_compactor_error", &[("compactor", compactor_id.clone())]);
        let errors_total = registry
            .counter("tgm_compactor_errors_total", &[("compactor", compactor_id.clone())]);
        let handle = {
            let stop = Arc::clone(&stop);
            let compactions = Arc::clone(&compactions);
            let last_error = Arc::clone(&last_error);
            let error_gauge = error_gauge.clone();
            let errors_total = errors_total.clone();
            thread::spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    let round = Instant::now();
                    match try_compact(&store, &cell, &cfg) {
                        Ok(true) => {
                            compactions.fetch_add(1, Ordering::SeqCst);
                            // A successful round supersedes any earlier
                            // transient failure: the health signal
                            // reflects the *current* state.
                            let had_error = last_error
                                .lock()
                                .unwrap_or_else(|p| p.into_inner())
                                .take()
                                .is_some();
                            if had_error {
                                error_gauge.set(0);
                                obs::event(
                                    "persist",
                                    "compactor_error_cleared",
                                    Some(compactor_id.clone()),
                                    "a later round succeeded",
                                );
                            }
                            obs::trace_ring().record(obs::TraceEvent {
                                ts_us: obs::trace::now_us(),
                                subsystem: "persist",
                                kind: "compaction_round",
                                tenant: Some(compactor_id.clone()),
                                dur_us: round.elapsed().as_micros().min(u64::MAX as u128)
                                    as u64,
                                detail: String::new(),
                            });
                            // Re-scan immediately: a burst of seals may
                            // have piled up more than one round's worth.
                        }
                        Ok(false) => thread::sleep(cfg.interval),
                        Err(e) => {
                            *last_error.lock().unwrap_or_else(|p| p.into_inner()) =
                                Some(e.to_string());
                            error_gauge.set(1);
                            errors_total.inc();
                            obs::event(
                                "persist",
                                "compactor_error",
                                Some(compactor_id.clone()),
                                e.to_string(),
                            );
                            thread::sleep(cfg.interval);
                        }
                    }
                }
            })
        };
        Compactor { stop, handle: Some(handle), compactions, last_error, error_gauge, errors_total }
    }

    /// Compaction rounds completed so far.
    pub fn compactions(&self) -> usize {
        self.compactions.load(Ordering::SeqCst)
    }

    /// Error from the most recent *failed* round, if no round has
    /// succeeded since (a successful round clears it — the signal
    /// reflects current health, not history). A failed round leaves the
    /// store exactly as it was; the thread keeps running.
    pub fn last_error(&self) -> Option<String> {
        self.last_error.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    /// Stop and join the background thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Compactor {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One compaction round; `Ok(true)` when a merged generation was
/// installed and published.
fn try_compact(
    store: &Mutex<SegmentedStorage>,
    cell: &SnapshotCell,
    cfg: &CompactorConfig,
) -> Result<bool> {
    // Scan under a short lock.
    let (segs, ids, num_nodes, granularity, dir) = {
        let s = store.lock().unwrap_or_else(|p| p.into_inner());
        // A poisoned store refuses every durable install: don't burn a
        // merge + pending write per poll just to have it rejected.
        if s.durability_poisoned() || s.num_sealed_segments() <= cfg.min_sealed.max(1) {
            return Ok(false);
        }
        let (segs, ids) = s.sealed_segments();
        (segs, ids, s.num_nodes(), s.granularity(), s.durable_dir().map(Path::to_path_buf))
    };

    // Plan the run off-lock (byte sizes are intrinsic to the immutable
    // Arcs, so planning needs no store access).
    let run = match cfg.strategy {
        CompactionStrategy::Full => 0..segs.len(),
        CompactionStrategy::Tiered { fanout } => {
            let sizes: Vec<usize> = segs.iter().map(|s| s.byte_size()).collect();
            match plan_tiered_run(&sizes, fanout) {
                Some(run) => run,
                None => return Ok(false), // at the tiering fixpoint
            }
        }
    };

    // Merge (and, durably, write + sync) off the write path.
    let merged = merge_segments(&segs[run.clone()], num_nodes, granularity, 0, Vec::new());
    let run_ids = ids[run].to_vec();
    drop(segs);
    let prewritten = match &dir {
        Some(d) => Some(write_pending_segment(d, &merged)?),
        None => None,
    };

    // Install + publish under the lock: O(1) swap, manifest replace,
    // atomic cell publish.
    let mut s = store.lock().unwrap_or_else(|p| p.into_inner());
    let installed = s.install_compacted(merged, &run_ids, prewritten.as_deref())?;
    if installed {
        s.publish_to(cell)?;
    }
    Ok(installed)
}

/// Write + sync the merged segment to a uniquely named pending file;
/// the install step renames it into place (same directory, so the
/// rename is atomic). Stale pending files are swept at recovery.
fn write_pending_segment(dir: &Path, seg: &crate::graph::GraphStorage) -> Result<PathBuf> {
    let n = NEXT_PENDING.fetch_add(1, Ordering::Relaxed);
    let path = dir.join(format!("compact-{n}{PENDING_SUFFIX}"));
    let write = |path: &Path| -> Result<()> {
        let bytes = format::encode_segment(seg);
        let mut f = std::fs::File::create(path)?;
        f.write_all(&bytes)?;
        f.sync_data()?;
        Ok(())
    };
    if let Err(e) = write(&path) {
        // Don't let the retry loop accumulate partial files (worst on a
        // full disk, where each leak worsens the failure itself).
        let _ = std::fs::remove_file(&path);
        return Err(e);
    }
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeEvent, SealPolicy};
    use crate::persist::{recover, DurabilityPolicy};
    use std::time::Instant;

    fn edge(t: i64, src: u32, dst: u32) -> EdgeEvent {
        EdgeEvent { t, src, dst, features: vec![t as f32] }
    }

    fn wait_until(deadline: Duration, mut cond: impl FnMut() -> bool) -> bool {
        let t0 = Instant::now();
        while t0.elapsed() < deadline {
            if cond() {
                return true;
            }
            thread::sleep(Duration::from_millis(5));
        }
        cond()
    }

    #[test]
    fn tiered_planning_picks_lowest_level_adjacent_runs() {
        // Equal sizes: one run spanning everything.
        assert_eq!(plan_tiered_run(&[100, 100, 100, 100], 4), Some(0..4));
        // Not enough same-level adjacency: fixpoint.
        assert_eq!(plan_tiered_run(&[100, 100, 100], 4), None);
        assert_eq!(plan_tiered_run(&[], 4), None);
        assert_eq!(plan_tiered_run(&[5000], 4), None);
        // A big old segment never re-merges with small new ones; the
        // small level drains first.
        assert_eq!(plan_tiered_run(&[40_000, 100, 110, 90, 100], 4), Some(1..5));
        // Two eligible levels: the lower (smaller bytes) wins even when
        // the higher one is older.
        let sizes = [40_000, 41_000, 39_000, 40_500, 100, 110, 90, 100];
        assert_eq!(plan_tiered_run(&sizes, 4), Some(4..8));
        // After that merge the higher level's run is next.
        let sizes = [40_000, 41_000, 39_000, 40_500, 1600];
        assert_eq!(plan_tiered_run(&sizes, 4), Some(0..4));
        // Fanout is clamped to >= 2 and respected.
        assert_eq!(plan_tiered_run(&[100, 100], 0), Some(0..2));
        assert_eq!(plan_tiered_run(&[100, 100, 100], 2), Some(0..3));
        // Runs must be contiguous: same level split by a bigger segment
        // does not merge across it.
        assert_eq!(plan_tiered_run(&[100, 100, 90_000, 100, 100], 4), None);
    }

    #[test]
    fn levels_are_monotonic_in_size() {
        assert_eq!(level_of(0, 4), 0);
        assert_eq!(level_of(3, 4), 0);
        assert_eq!(level_of(4, 4), 1);
        assert_eq!(level_of(15, 4), 1);
        assert_eq!(level_of(16, 4), 2);
        for w in [1usize, 10, 100, 1000, 10_000].windows(2) {
            assert!(level_of(w[0], 4) <= level_of(w[1], 4));
        }
    }

    /// A tiered background compactor drains the low level, installs
    /// mid-stack runs correctly, and reaches a fixpoint instead of
    /// endlessly rewriting the big old segments.
    #[test]
    fn tiered_background_compactor_reaches_a_fixpoint() {
        let mut st = SegmentedStorage::new(8, SealPolicy::by_events(4));
        for i in 0..96i64 {
            st.append_edge(edge(i * 10, (i % 5) as u32, 5 + (i % 3) as u32)).unwrap();
        }
        assert_eq!(st.num_sealed_segments(), 24);
        let cell = SnapshotCell::new();
        let baseline = st.publish_to(&cell).unwrap();
        let store = Arc::new(Mutex::new(st));
        let compactor = Compactor::spawn(
            Arc::clone(&store),
            cell.clone(),
            CompactorConfig {
                min_sealed: 1,
                interval: Duration::from_millis(1),
                strategy: CompactionStrategy::Tiered { fanout: 4 },
            },
        );
        // Fixpoint: every level holds < 4 same-level adjacent segments.
        assert!(
            wait_until(Duration::from_secs(10), || {
                let s = store.lock().unwrap();
                let sizes: Vec<usize> =
                    s.sealed_segments().0.iter().map(|g| g.byte_size()).collect();
                plan_tiered_run(&sizes, 4).is_none()
            }),
            "compactor never reached the tiering fixpoint: {:?}",
            compactor.last_error()
        );
        let rounds = compactor.compactions();
        compactor.stop();
        assert!(rounds >= 1, "at least the base level must have merged");
        let mut s = store.lock().unwrap();
        let sealed = s.num_sealed_segments();
        assert!(sealed < 24, "tiering must have shrunk the stack ({sealed})");
        assert!(sealed >= 1);
        // Content is untouched, and the published generation advanced.
        let latest = cell.pin().unwrap();
        assert!(latest.generation() > baseline.generation());
        assert_eq!(s.snapshot().unwrap().edge_ts(), baseline.edge_ts());
        assert_eq!(latest.edge_feats(), baseline.edge_feats());
    }

    #[test]
    fn background_compactor_merges_and_publishes() {
        let mut st = SegmentedStorage::new(8, SealPolicy::by_events(4));
        for i in 0..40i64 {
            st.append_edge(edge(i * 10, (i % 5) as u32, 5 + (i % 3) as u32)).unwrap();
        }
        assert!(st.num_sealed_segments() >= 8);
        let cell = SnapshotCell::new();
        let baseline = st.publish_to(&cell).unwrap();
        let store = Arc::new(Mutex::new(st));

        let compactor = Compactor::spawn(
            Arc::clone(&store),
            cell.clone(),
            CompactorConfig {
                min_sealed: 2,
                interval: Duration::from_millis(1),
                ..CompactorConfig::default()
            },
        );
        assert!(
            wait_until(Duration::from_secs(10), || compactor.compactions() > 0),
            "compactor never ran: {:?}",
            compactor.last_error()
        );
        compactor.stop();

        let mut s = store.lock().unwrap();
        assert_eq!(s.num_sealed_segments(), 1);
        let latest = cell.pin().expect("a compacted generation was published");
        assert!(latest.generation() > baseline.generation());
        assert_eq!(latest.edge_ts(), baseline.edge_ts());
        assert_eq!(latest.edge_feats(), baseline.edge_feats());
        assert_eq!(s.snapshot().unwrap().edge_ts(), baseline.edge_ts());
        // The pinned old generation still reads its own (pre-compaction)
        // segment stack.
        assert!(baseline.num_segments() >= 8);
    }

    /// Satellite (ISSUE 9): a failed round raises the per-compactor
    /// error gauge and bumps the monotonic counter; a later successful
    /// round clears the gauge (never the counter), mirroring
    /// `last_error`'s set-then-clear contract as registry series.
    #[test]
    fn compactor_error_metrics_set_and_clear_with_round_outcomes() {
        let dir = std::env::temp_dir()
            .join(format!("tgm_persist_compactor_err_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut st = SegmentedStorage::new(8, SealPolicy::by_events(4))
            .with_durability(DurabilityPolicy::new(&dir))
            .unwrap();
        for i in 0..32i64 {
            st.append_edge(edge(i * 10, (i % 5) as u32, 5 + (i % 3) as u32)).unwrap();
        }
        let cell = SnapshotCell::new();
        let store = Arc::new(Mutex::new(st));
        // Yank the directory: each round's pending-segment write fails
        // (the store itself is not poisoned — the failure is on the
        // compactor's side of the protocol, before any install).
        std::fs::remove_dir_all(&dir).unwrap();
        let compactor = Compactor::spawn(
            Arc::clone(&store),
            cell.clone(),
            CompactorConfig {
                min_sealed: 1,
                interval: Duration::from_millis(1),
                ..CompactorConfig::default()
            },
        );
        assert!(
            wait_until(Duration::from_secs(10), || compactor.error_gauge.get() == 1),
            "a failed round must raise the error gauge"
        );
        assert!(compactor.errors_total.get() >= 1);
        assert!(compactor.last_error().is_some());

        // Restore the directory: a later round succeeds and clears the
        // gauge while the counter stays put.
        std::fs::create_dir_all(&dir).unwrap();
        assert!(
            wait_until(Duration::from_secs(10), || {
                compactor.error_gauge.get() == 0 && compactor.compactions() > 0
            }),
            "a successful round must clear the gauge: {:?}",
            compactor.last_error()
        );
        assert!(compactor.last_error().is_none());
        assert!(compactor.errors_total.get() >= 1, "the counter is monotonic");
        compactor.stop();
    }

    #[test]
    fn durable_background_compaction_survives_recovery() {
        let dir = std::env::temp_dir()
            .join(format!("tgm_persist_bg_compact_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut st = SegmentedStorage::new(8, SealPolicy::by_events(4))
            .with_durability(DurabilityPolicy::new(&dir))
            .unwrap();
        for i in 0..32i64 {
            st.append_edge(edge(i * 10, (i % 5) as u32, 5 + (i % 3) as u32)).unwrap();
        }
        let expect = st.snapshot().unwrap().edge_ts();
        let cell = SnapshotCell::new();
        let store = Arc::new(Mutex::new(st));
        let compactor = Compactor::spawn(
            Arc::clone(&store),
            cell.clone(),
            CompactorConfig {
                min_sealed: 1,
                interval: Duration::from_millis(1),
                ..CompactorConfig::default()
            },
        );
        assert!(
            wait_until(Duration::from_secs(10), || {
                store.lock().unwrap().num_sealed_segments() == 1
            }),
            "never compacted down to one segment: {:?}",
            compactor.last_error()
        );
        compactor.stop();
        drop(store);

        let mut rec = recover(SealPolicy::by_events(4), DurabilityPolicy::new(&dir)).unwrap();
        assert_eq!(rec.num_sealed_segments(), 1);
        assert_eq!(rec.snapshot().unwrap().edge_ts(), expect);
        // No pending compaction file survives recovery.
        let pending = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().ends_with(PENDING_SUFFIX))
            .count();
        assert_eq!(pending, 0);
    }
}
