//! Versioned binary codecs for the durable segment store.
//!
//! Three file kinds share one style: an 8-byte magic, a `u32` format
//! version, a length-prefixed payload, and a trailing FNV-1a checksum
//! over the payload. Everything is little-endian. Decoding is strict:
//! short files, bad magic, unknown versions and checksum mismatches all
//! surface as [`TgmError::Persist`] — never a panic, never silent
//! garbage.
//!
//! * **Segment files** (`seg-NNNNNN.tgm`) hold one sealed
//!   [`GraphStorage`] as raw columns: the same SoA layout the in-memory
//!   segment uses (edge ts/src/dst + flattened edge-feature rows, node
//!   event ts/id + feature rows), written once at seal time and
//!   immutable thereafter. The timestamp index and per-node indices are
//!   *not* stored; they are rebuilt on load (cheap, and keeps the format
//!   independent of in-memory acceleration structures).
//! * **The manifest** (`MANIFEST`) names the live segment files (their
//!   sequence numbers, oldest first), the store metadata that is not
//!   derivable from the segments (node-id space, fixed granularity,
//!   static features), the generation at the last durable structural
//!   change, and the WAL epoch it expects (see [`super::wal`]). It is
//!   replaced atomically (tmp file + rename) on every seal and
//!   compaction, so a reader always sees either the old or the new
//!   store, never a mix.

use crate::error::{Result, TgmError};
use crate::graph::storage::{Col, GraphStorage};
use crate::persist::mmap::{self, MappedSlice, Mmap};
use crate::persist::SegmentBacking;
use crate::util::TimeGranularity;
use std::io::Write;
use std::path::Path;
use std::sync::Arc;

/// On-disk format version of the manifest, WAL and static-feature
/// files.
pub const FORMAT_VERSION: u32 = 1;

/// On-disk format version of **segment** files. v1 packed the columns
/// back-to-back (decodable only into heap copies); v2 pads each column
/// to its element alignment at file-absolute offsets, so a page-aligned
/// mmap of the file can serve every column as a typed slice with zero
/// copies (see [`map_segment`]). v1 files remain readable.
pub const SEGMENT_FORMAT_VERSION: u32 = 2;

/// Bytes of frame header before the payload (magic + version + length).
const FRAME_HEADER_LEN: usize = 20;

const SEGMENT_MAGIC: &[u8; 8] = b"TGMSEG01";
const MANIFEST_MAGIC: &[u8; 8] = b"TGMMAN01";
const STATIC_MAGIC: &[u8; 8] = b"TGMSTA01";

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// FNV-1a 64-bit checksum (dependency-free corruption detection; this
/// guards against torn writes and bit rot, not adversaries).
pub fn checksum(bytes: &[u8]) -> u64 {
    checksum_seeded(FNV_OFFSET, bytes)
}

/// Fold `bytes` into a running FNV-1a state, so multi-part inputs (the
/// WAL's kind byte + payload) checksum without concatenating into a
/// scratch buffer first.
pub fn checksum_seeded(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Encode a granularity as one byte.
fn granularity_code(g: TimeGranularity) -> u8 {
    match g {
        TimeGranularity::Event => 0,
        TimeGranularity::Second => 1,
        TimeGranularity::Minute => 2,
        TimeGranularity::Hour => 3,
        TimeGranularity::Day => 4,
        TimeGranularity::Week => 5,
        TimeGranularity::Year => 6,
    }
}

fn granularity_from_code(c: u8) -> Result<TimeGranularity> {
    Ok(match c {
        0 => TimeGranularity::Event,
        1 => TimeGranularity::Second,
        2 => TimeGranularity::Minute,
        3 => TimeGranularity::Hour,
        4 => TimeGranularity::Day,
        5 => TimeGranularity::Week,
        6 => TimeGranularity::Year,
        other => {
            return Err(TgmError::Persist(format!("unknown granularity code {other}")));
        }
    })
}

// ----------------------------------------------------------------------
// byte-level encoder / decoder
// ----------------------------------------------------------------------

/// Append-only little-endian byte sink.
#[derive(Default)]
pub(crate) struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub(crate) fn new() -> Enc {
        Enc::default()
    }

    pub(crate) fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub(crate) fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn u32s(&mut self, vs: &[u32]) {
        for &v in vs {
            self.u32(v);
        }
    }

    pub(crate) fn i64s(&mut self, vs: &[i64]) {
        for &v in vs {
            self.i64(v);
        }
    }

    pub(crate) fn f32s(&mut self, vs: &[f32]) {
        for &v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// Zero-pad until the **file** offset of the next byte (frame
    /// header + payload so far) is a multiple of `align` — the v2
    /// segment layout's column-alignment primitive.
    pub(crate) fn pad_to_file_align(&mut self, align: usize) {
        while (FRAME_HEADER_LEN + self.buf.len()) % align != 0 {
            self.buf.push(0);
        }
    }

    pub(crate) fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Strict little-endian cursor; every read error is a typed
/// [`TgmError::Persist`].
pub(crate) struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
    what: &'static str,
}

impl<'a> Dec<'a> {
    pub(crate) fn new(buf: &'a [u8], what: &'static str) -> Dec<'a> {
        Dec { buf, pos: 0, what }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(TgmError::Persist(format!(
                "{} truncated: wanted {} bytes at offset {}, have {}",
                self.what,
                n,
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub(crate) fn i64(&mut self) -> Result<i64> {
        Ok(self.u64()? as i64)
    }

    /// A length `n` read from the file, validated against what the
    /// buffer can still hold (guards against allocating garbage sizes).
    fn checked_len(&self, n: u64, unit: usize) -> Result<usize> {
        let n = usize::try_from(n)
            .map_err(|_| TgmError::Persist(format!("{}: count {n} overflows", self.what)))?;
        if n.saturating_mul(unit) > self.buf.len() - self.pos {
            return Err(TgmError::Persist(format!(
                "{}: declared {n} x {unit}-byte values but only {} bytes remain",
                self.what,
                self.buf.len() - self.pos
            )));
        }
        Ok(n)
    }

    pub(crate) fn u32s(&mut self, n: u64) -> Result<Vec<u32>> {
        let n = self.checked_len(n, 4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u32()?);
        }
        Ok(out)
    }

    pub(crate) fn i64s(&mut self, n: u64) -> Result<Vec<i64>> {
        let n = self.checked_len(n, 8)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.i64()?);
        }
        Ok(out)
    }

    pub(crate) fn f32s(&mut self, n: u64) -> Result<Vec<f32>> {
        let n = self.checked_len(n, 4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let b = self.take(4)?;
            out.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
        }
        Ok(out)
    }

    /// Skip the zero padding [`Enc::pad_to_file_align`] emitted (the
    /// cursor's payload position plus the frame header is the file
    /// offset).
    pub(crate) fn skip_file_pad(&mut self, align: usize) -> Result<()> {
        while (FRAME_HEADER_LEN + self.pos) % align != 0 {
            self.take(1)?;
        }
        Ok(())
    }

    /// Payload-relative cursor position.
    pub(crate) fn pos(&self) -> usize {
        self.pos
    }

    /// Bytes left after the cursor (lenient decoders check this before
    /// reading fields appended by newer writers).
    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub(crate) fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(TgmError::Persist(format!(
                "{}: {} trailing bytes after payload",
                self.what,
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

// ----------------------------------------------------------------------
// framing: magic + version + payload + checksum
// ----------------------------------------------------------------------

/// Wrap a payload in the shared frame at the default format version.
fn frame(magic: &[u8; 8], payload: Vec<u8>) -> Vec<u8> {
    frame_versioned(magic, FORMAT_VERSION, payload)
}

/// Wrap a payload in the shared frame at an explicit version (segment
/// files write [`SEGMENT_FORMAT_VERSION`]).
fn frame_versioned(magic: &[u8; 8], version: u32, payload: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(payload.len() + 28);
    out.extend_from_slice(magic);
    out.extend_from_slice(&version.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    let sum = checksum(&payload);
    out.extend_from_slice(&payload);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Validate the frame and return `(version, payload)`. Versions in
/// `1..=max_version` are accepted; callers branch on the version for
/// layout differences.
fn unframe<'a>(
    magic: &[u8; 8],
    bytes: &'a [u8],
    what: &'static str,
    max_version: u32,
) -> Result<(u32, &'a [u8])> {
    if bytes.len() < 28 {
        return Err(TgmError::Persist(format!("{what} too short ({} bytes)", bytes.len())));
    }
    if &bytes[..8] != magic {
        return Err(TgmError::Persist(format!("{what} has wrong magic (not a TGM file?)")));
    }
    let version = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]);
    if version == 0 || version > max_version {
        return Err(TgmError::Persist(format!(
            "{what} format version {version} unsupported (this build reads <= {max_version})"
        )));
    }
    let len = u64::from_le_bytes([
        bytes[12], bytes[13], bytes[14], bytes[15], bytes[16], bytes[17], bytes[18], bytes[19],
    ]);
    let len = usize::try_from(len)
        .ok()
        .filter(|l| l.checked_add(28).is_some())
        .ok_or_else(|| TgmError::Persist(format!("{what}: absurd payload length {len}")))?;
    if bytes.len() != 20 + len + 8 {
        return Err(TgmError::Persist(format!(
            "{what} torn: header declares {len}-byte payload, file holds {}",
            bytes.len().saturating_sub(28)
        )));
    }
    let payload = &bytes[20..20 + len];
    let stored = u64::from_le_bytes([
        bytes[20 + len],
        bytes[21 + len],
        bytes[22 + len],
        bytes[23 + len],
        bytes[24 + len],
        bytes[25 + len],
        bytes[26 + len],
        bytes[27 + len],
    ]);
    if checksum(payload) != stored {
        return Err(TgmError::Persist(format!("{what} checksum mismatch (corrupt file)")));
    }
    Ok((version, payload))
}

/// Write `bytes` to `path` atomically: write + sync a sibling tmp file,
/// rename over the target (crash leaves either the old file or the new
/// one, never a torn mix), then sync the parent directory so the rename
/// itself survives a power loss.
pub(crate) fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = tmp_sibling(path);
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_data()?;
    }
    std::fs::rename(&tmp, path)?;
    sync_parent_dir(path)
}

/// fsync the directory containing `path`: a rename is only durable once
/// its directory entry reaches disk. Platforms whose directory handles
/// reject fsync surface the error as [`TgmError::Persist`]-compatible
/// IO, which callers treat like any other durable-write failure.
pub(crate) fn sync_parent_dir(path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        let parent = if parent.as_os_str().is_empty() { Path::new(".") } else { parent };
        std::fs::File::open(parent)?.sync_all()?;
    }
    Ok(())
}

/// Sibling `.tmp` path used by the atomic-write protocol.
pub(crate) fn tmp_sibling(path: &Path) -> std::path::PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

// ----------------------------------------------------------------------
// segment files
// ----------------------------------------------------------------------

/// Segment-payload header fields shared by the heap and mmap decoders.
struct SegmentHeader {
    num_nodes: usize,
    granularity: TimeGranularity,
    num_edges: u64,
    edge_feat_dim: usize,
    num_node_events: u64,
    node_feat_dim: usize,
}

fn read_segment_header(d: &mut Dec<'_>) -> Result<SegmentHeader> {
    Ok(SegmentHeader {
        num_nodes: d.u64()? as usize,
        granularity: granularity_from_code(d.u8()?)?,
        num_edges: d.u64()?,
        edge_feat_dim: d.u32()? as usize,
        num_node_events: d.u64()?,
        node_feat_dim: d.u32()? as usize,
    })
}

/// Validate decoded (or mapped) segment columns: time-sorted, non-empty,
/// node ids in range.
fn validate_segment_columns(
    num_nodes: usize,
    ts: &[i64],
    src: &[u32],
    dst: &[u32],
    nts: &[i64],
    nid: &[u32],
) -> Result<()> {
    if ts.windows(2).any(|w| w[0] > w[1]) || nts.windows(2).any(|w| w[0] > w[1]) {
        return Err(TgmError::Persist("segment columns are not time-sorted".into()));
    }
    if ts.is_empty() {
        return Err(TgmError::Persist("segment file holds no edge events".into()));
    }
    if src.iter().chain(dst.iter()).any(|&n| n as usize >= num_nodes)
        || nid.iter().any(|&n| n as usize >= num_nodes)
    {
        return Err(TgmError::Persist(format!(
            "segment references a node id >= num_nodes={num_nodes}"
        )));
    }
    Ok(())
}

/// Encode one sealed segment into the versioned columnar format (v2:
/// every column starts at a file offset aligned for its element type,
/// so [`map_segment`] can serve it zero-copy).
pub fn encode_segment(seg: &GraphStorage) -> Vec<u8> {
    let mut p = Enc::new();
    p.u64(seg.num_nodes() as u64);
    p.u8(granularity_code(seg.granularity()));
    p.u64(seg.num_edges() as u64);
    p.u32(seg.edge_feat_dim() as u32);
    p.u64(seg.num_node_events() as u64);
    p.u32(seg.node_feat_dim() as u32);
    p.pad_to_file_align(8);
    p.i64s(seg.edge_ts());
    p.u32s(seg.edge_src());
    p.u32s(seg.edge_dst());
    p.f32s(seg.edge_feats());
    p.pad_to_file_align(8);
    p.i64s(seg.node_event_ts());
    p.u32s(seg.node_event_ids());
    p.f32s(seg.node_event_feats());
    frame_versioned(SEGMENT_MAGIC, SEGMENT_FORMAT_VERSION, p.into_bytes())
}

/// Decode a segment file body (v1 or v2) into heap-backed columns,
/// rebuilding the in-memory acceleration indices.
pub fn decode_segment(bytes: &[u8]) -> Result<GraphStorage> {
    let (version, payload) =
        unframe(SEGMENT_MAGIC, bytes, "segment file", SEGMENT_FORMAT_VERSION)?;
    let mut d = Dec::new(payload, "segment payload");
    let h = read_segment_header(&mut d)?;
    if version >= 2 {
        d.skip_file_pad(8)?;
    }
    let ts = d.i64s(h.num_edges)?;
    let src = d.u32s(h.num_edges)?;
    let dst = d.u32s(h.num_edges)?;
    let feats = d.f32s(h.num_edges.saturating_mul(h.edge_feat_dim as u64))?;
    if version >= 2 {
        d.skip_file_pad(8)?;
    }
    let nts = d.i64s(h.num_node_events)?;
    let nid = d.u32s(h.num_node_events)?;
    let nfeats = d.f32s(h.num_node_events.saturating_mul(h.node_feat_dim as u64))?;
    d.done()?;
    validate_segment_columns(h.num_nodes, &ts, &src, &dst, &nts, &nid)?;
    Ok(GraphStorage::from_sorted_columns(
        ts,
        src,
        dst,
        h.edge_feat_dim,
        feats,
        nts,
        nid,
        h.node_feat_dim,
        nfeats,
        h.num_nodes,
        0,
        Vec::new(),
        h.granularity,
    ))
}

/// Open a v2 segment file as an mmap-backed [`GraphStorage`]: the
/// checksum is verified once through the page cache, then every column
/// is served as a typed slice straight over the mapping — no heap
/// copies at recovery or compaction install. v1 files (packed, hence
/// unaligned) transparently decode into heap columns instead.
pub fn map_segment(path: &Path) -> Result<GraphStorage> {
    let map = Arc::new(Mmap::open(path)?);
    let (version, payload) =
        unframe(SEGMENT_MAGIC, map.bytes(), "segment file", SEGMENT_FORMAT_VERSION)?;
    if version < 2 {
        return decode_segment(map.bytes());
    }
    let payload_base = FRAME_HEADER_LEN; // payload starts right after the frame header
    let mut d = Dec::new(payload, "segment payload");
    let h = read_segment_header(&mut d)?;
    d.skip_file_pad(8)?;

    let e = usize::try_from(h.num_edges)
        .map_err(|_| TgmError::Persist("segment edge count overflows".into()))?;
    let ne = usize::try_from(h.num_node_events)
        .map_err(|_| TgmError::Persist("segment node-event count overflows".into()))?;
    // Guard the offset arithmetic below against declared counts larger
    // than the payload could possibly hold.
    let need = (e as u128) * (16 + 4 * h.edge_feat_dim as u128)
        + (ne as u128) * (12 + 4 * h.node_feat_dim as u128);
    if need > payload.len() as u128 {
        return Err(TgmError::Persist(format!(
            "segment declares {need} column bytes but the payload holds {}",
            payload.len()
        )));
    }
    let col = |off: usize| payload_base + off;

    let ts_off = d.pos();
    let src_off = ts_off + e * 8;
    let dst_off = src_off + e * 4;
    let feats_off = dst_off + e * 4;
    let mut after = feats_off + e * h.edge_feat_dim * 4;
    while (payload_base + after) % 8 != 0 {
        after += 1;
    }
    let nts_off = after;
    let nid_off = nts_off + ne * 8;
    let nfeats_off = nid_off + ne * 4;
    let end = nfeats_off + ne * h.node_feat_dim * 4;
    if end != payload.len() {
        return Err(TgmError::Persist(format!(
            "segment payload is {} bytes but the columns need {end}",
            payload.len()
        )));
    }

    let ts: MappedSlice<i64> = MappedSlice::new(Arc::clone(&map), col(ts_off), e)?;
    let src: MappedSlice<u32> = MappedSlice::new(Arc::clone(&map), col(src_off), e)?;
    let dst: MappedSlice<u32> = MappedSlice::new(Arc::clone(&map), col(dst_off), e)?;
    let feats: MappedSlice<f32> =
        MappedSlice::new(Arc::clone(&map), col(feats_off), e * h.edge_feat_dim)?;
    let nts: MappedSlice<i64> = MappedSlice::new(Arc::clone(&map), col(nts_off), ne)?;
    let nid: MappedSlice<u32> = MappedSlice::new(Arc::clone(&map), col(nid_off), ne)?;
    let nfeats: MappedSlice<f32> =
        MappedSlice::new(Arc::clone(&map), col(nfeats_off), ne * h.node_feat_dim)?;

    validate_segment_columns(
        h.num_nodes,
        ts.as_slice(),
        src.as_slice(),
        dst.as_slice(),
        nts.as_slice(),
        nid.as_slice(),
    )?;
    Ok(GraphStorage::from_backed_columns(
        Col::Mapped(ts),
        Col::Mapped(src),
        Col::Mapped(dst),
        h.edge_feat_dim,
        Col::Mapped(feats),
        Col::Mapped(nts),
        Col::Mapped(nid),
        h.node_feat_dim,
        Col::Mapped(nfeats),
        h.num_nodes,
        h.granularity,
    ))
}

/// Read + decode one segment file into heap columns.
pub fn read_segment(path: &Path) -> Result<GraphStorage> {
    let bytes = std::fs::read(path)
        .map_err(|e| TgmError::Persist(format!("cannot read segment {}: {e}", path.display())))?;
    decode_segment(&bytes)
}

/// Open one segment file with the requested backing. `Mmap` serves the
/// columns straight from the page cache ([`map_segment`]); on platforms
/// without mmap support it degrades to the heap decoder — the served
/// bytes are identical either way.
pub fn read_segment_backed(path: &Path, backing: SegmentBacking) -> Result<GraphStorage> {
    match backing {
        SegmentBacking::Heap => read_segment(path),
        SegmentBacking::Mmap => {
            if mmap::supported() {
                map_segment(path)
            } else {
                read_segment(path)
            }
        }
    }
}

/// Write one segment file atomically.
pub fn write_segment(path: &Path, seg: &GraphStorage) -> Result<()> {
    write_atomic(path, &encode_segment(seg))
}

// ----------------------------------------------------------------------
// the static-feature file
// ----------------------------------------------------------------------

/// Encode the write-once static node-feature matrix (kept out of the
/// manifest so seals and compactions never rewrite it).
pub fn encode_static(dim: usize, feats: &[f32]) -> Vec<u8> {
    let mut p = Enc::new();
    p.u32(dim as u32);
    p.u64(feats.len() as u64);
    p.f32s(feats);
    frame(STATIC_MAGIC, p.into_bytes())
}

/// Decode a static-feature file body: `(dim, feats)`.
pub fn decode_static(bytes: &[u8]) -> Result<(usize, Vec<f32>)> {
    let (_, payload) = unframe(STATIC_MAGIC, bytes, "static-feature file", FORMAT_VERSION)?;
    let mut d = Dec::new(payload, "static-feature payload");
    let dim = d.u32()? as usize;
    let n = d.u64()?;
    let feats = d.f32s(n)?;
    d.done()?;
    Ok((dim, feats))
}

/// Read + decode the static-feature file.
pub fn read_static(path: &Path) -> Result<(usize, Vec<f32>)> {
    let bytes = std::fs::read(path).map_err(|e| {
        TgmError::Persist(format!("cannot read static features {}: {e}", path.display()))
    })?;
    decode_static(&bytes)
}

/// Write the static-feature file atomically.
pub fn write_static(path: &Path, dim: usize, feats: &[f32]) -> Result<()> {
    write_atomic(path, &encode_static(dim, feats))
}

// ----------------------------------------------------------------------
// the manifest
// ----------------------------------------------------------------------

/// Store metadata persisted in `MANIFEST`: everything recovery cannot
/// derive from the segment files themselves. The static node-feature
/// *matrix* lives in its own write-once file (`static.tgm`) so the
/// manifest — rewritten on every seal and compaction — stays a few
/// hundred bytes; only the dimension is recorded here.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// Node-id space of the store.
    pub num_nodes: usize,
    /// Granularity fixed up front (`None` = inferred from the stream).
    pub fixed_granularity: Option<TimeGranularity>,
    /// Width of the static node-feature matrix (0 = none; the matrix
    /// itself is in the static-feature file).
    pub static_feat_dim: usize,
    /// Store generation at the last durable structural change
    /// (seal/compact); recovery adds one per replayed WAL record on top.
    pub generation: u64,
    /// WAL incarnation this manifest expects. A WAL header with a lower
    /// epoch predates the last seal (its events are already in a sealed
    /// segment file) and is discarded on recovery.
    pub wal_epoch: u64,
    /// Next segment sequence number to allocate.
    pub next_seq: u64,
    /// Live segment files (sequence numbers, oldest first).
    pub segments: Vec<u64>,
    /// Number of current-epoch WAL records acknowledged at the moment
    /// this manifest was written. Seals reset the WAL (epoch+1), so a
    /// seal manifest records 0; a compaction manifest written mid-epoch
    /// records how many of the epoch's appends its `generation` already
    /// counts. `generation - wal_records` is therefore the generation
    /// *before* any current-epoch append — the anchor both recovery and
    /// a tailing replica use to reconstruct exact generations (+1 per
    /// replayed record). Encoded after the segment list and decoded
    /// leniently (absent in pre-replication manifests ⇒ 0), so the
    /// format version is unchanged and old stores stay readable.
    pub wal_records: u64,
}

/// Encode the manifest.
pub fn encode_manifest(m: &Manifest) -> Vec<u8> {
    let mut p = Enc::new();
    p.u64(m.num_nodes as u64);
    p.u8(match m.fixed_granularity {
        None => 0xff,
        Some(g) => granularity_code(g),
    });
    p.u32(m.static_feat_dim as u32);
    p.u64(m.generation);
    p.u64(m.wal_epoch);
    p.u64(m.next_seq);
    p.u64(m.segments.len() as u64);
    for &s in &m.segments {
        p.u64(s);
    }
    p.u64(m.wal_records);
    frame(MANIFEST_MAGIC, p.into_bytes())
}

/// Decode a manifest file body.
pub fn decode_manifest(bytes: &[u8]) -> Result<Manifest> {
    let (_, payload) = unframe(MANIFEST_MAGIC, bytes, "manifest", FORMAT_VERSION)?;
    let mut d = Dec::new(payload, "manifest payload");
    let num_nodes = d.u64()? as usize;
    let fixed_granularity = match d.u8()? {
        0xff => None,
        code => Some(granularity_from_code(code)?),
    };
    let static_feat_dim = d.u32()? as usize;
    let generation = d.u64()?;
    let wal_epoch = d.u64()?;
    let next_seq = d.u64()?;
    let nsegs = d.u64()?;
    let mut segments = Vec::new();
    for _ in 0..nsegs {
        segments.push(d.u64()?);
    }
    // Pre-replication manifests end here; newer ones append the
    // current-epoch WAL record count.
    let wal_records = if d.remaining() > 0 { d.u64()? } else { 0 };
    d.done()?;
    Ok(Manifest {
        num_nodes,
        fixed_granularity,
        static_feat_dim,
        generation,
        wal_epoch,
        next_seq,
        segments,
        wal_records,
    })
}

/// Read + decode the manifest at `path`.
pub fn read_manifest(path: &Path) -> Result<Manifest> {
    let bytes = std::fs::read(path)
        .map_err(|e| TgmError::Persist(format!("cannot read manifest {}: {e}", path.display())))?;
    decode_manifest(&bytes)
}

/// Write the manifest atomically.
pub fn write_manifest(path: &Path, m: &Manifest) -> Result<()> {
    write_atomic(path, &encode_manifest(m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::events::{EdgeEvent, NodeEvent};

    fn sample_segment() -> GraphStorage {
        let edges = vec![
            EdgeEvent { t: 10, src: 0, dst: 1, features: vec![1.0, 2.0] },
            EdgeEvent { t: 20, src: 1, dst: 2, features: vec![3.0, 4.0] },
            EdgeEvent { t: 20, src: 2, dst: 0, features: vec![5.0, 6.0] },
        ];
        let nodes = vec![NodeEvent { t: 15, node: 1, features: vec![9.0] }];
        GraphStorage::from_events(edges, nodes, 4, None, None).unwrap()
    }

    #[test]
    fn segment_round_trip_is_byte_faithful() {
        let seg = sample_segment();
        let bytes = encode_segment(&seg);
        let back = decode_segment(&bytes).unwrap();
        assert_eq!(back.num_nodes(), seg.num_nodes());
        assert_eq!(back.granularity(), seg.granularity());
        assert_eq!(back.edge_ts(), seg.edge_ts());
        assert_eq!(back.edge_src(), seg.edge_src());
        assert_eq!(back.edge_dst(), seg.edge_dst());
        assert_eq!(back.edge_feats(), seg.edge_feats());
        assert_eq!(back.node_event_ts(), seg.node_event_ts());
        assert_eq!(back.node_event_ids(), seg.node_event_ids());
        assert_eq!(back.node_event_feats(), seg.node_event_feats());
        assert_eq!(back.num_unique_timestamps(), seg.num_unique_timestamps());
    }

    #[test]
    fn corrupt_and_torn_segments_are_typed_errors() {
        let bytes = encode_segment(&sample_segment());
        // Flip one payload byte: checksum mismatch.
        let mut corrupt = bytes.clone();
        corrupt[25] ^= 0x40;
        let err = decode_segment(&corrupt).unwrap_err();
        assert!(matches!(err, TgmError::Persist(_)), "{err}");
        assert!(err.to_string().contains("checksum"), "{err}");
        // Truncate: torn file.
        let err = decode_segment(&bytes[..bytes.len() - 3]).unwrap_err();
        assert!(matches!(err, TgmError::Persist(_)), "{err}");
        // Wrong magic.
        let mut magic = bytes.clone();
        magic[0] = b'X';
        assert!(decode_segment(&magic).is_err());
        // Unsupported version.
        let mut ver = bytes.clone();
        ver[8] = 0xee;
        let err = decode_segment(&ver).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    /// v1 layout (packed, no alignment padding) kept as a test-only
    /// encoder so compatibility with PR-4 era files stays pinned.
    fn encode_segment_v1(seg: &GraphStorage) -> Vec<u8> {
        let mut p = Enc::new();
        p.u64(seg.num_nodes() as u64);
        p.u8(granularity_code(seg.granularity()));
        p.u64(seg.num_edges() as u64);
        p.u32(seg.edge_feat_dim() as u32);
        p.u64(seg.num_node_events() as u64);
        p.u32(seg.node_feat_dim() as u32);
        p.i64s(seg.edge_ts());
        p.u32s(seg.edge_src());
        p.u32s(seg.edge_dst());
        p.f32s(seg.edge_feats());
        p.i64s(seg.node_event_ts());
        p.u32s(seg.node_event_ids());
        p.f32s(seg.node_event_feats());
        frame_versioned(SEGMENT_MAGIC, 1, p.into_bytes())
    }

    fn assert_same_columns(a: &GraphStorage, b: &GraphStorage) {
        assert_eq!(a.num_nodes(), b.num_nodes());
        assert_eq!(a.granularity(), b.granularity());
        assert_eq!(a.edge_ts(), b.edge_ts());
        assert_eq!(a.edge_src(), b.edge_src());
        assert_eq!(a.edge_dst(), b.edge_dst());
        assert_eq!(a.edge_feats(), b.edge_feats());
        assert_eq!(a.node_event_ts(), b.node_event_ts());
        assert_eq!(a.node_event_ids(), b.node_event_ids());
        assert_eq!(a.node_event_feats(), b.node_event_feats());
        assert_eq!(a.num_unique_timestamps(), b.num_unique_timestamps());
    }

    fn seg_file(tag: &str, bytes: &[u8]) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("tgm_format_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(tag);
        std::fs::write(&path, bytes).unwrap();
        path
    }

    #[test]
    fn v1_segments_stay_readable() {
        let seg = sample_segment();
        let bytes = encode_segment_v1(&seg);
        let back = decode_segment(&bytes).unwrap();
        assert_same_columns(&back, &seg);
        assert!(!back.is_mapped());
        // The mmap entry point degrades v1 files to heap columns.
        let path = seg_file("v1.tgm", &bytes);
        let mapped = map_segment(&path).unwrap();
        assert_same_columns(&mapped, &seg);
        assert!(!mapped.is_mapped());
    }

    #[test]
    fn mapped_segments_serve_byte_identical_columns() {
        if !crate::persist::mmap::supported() {
            return;
        }
        let seg = sample_segment();
        let path = seg_file("v2.tgm", &encode_segment(&seg));
        let mapped = map_segment(&path).unwrap();
        assert!(mapped.is_mapped(), "v2 files must serve zero-copy");
        assert_same_columns(&mapped, &seg);
        // Same result through the backing selector, both ways.
        let heap = read_segment_backed(&path, SegmentBacking::Heap).unwrap();
        assert!(!heap.is_mapped());
        assert_same_columns(&heap, &mapped);
        let again = read_segment_backed(&path, SegmentBacking::Mmap).unwrap();
        assert_same_columns(&again, &mapped);
        // Time queries and per-node lookups run unchanged over the map.
        assert_eq!(mapped.edge_range(10, 21), seg.edge_range(10, 21));
        assert_eq!(
            mapped.latest_node_features_before(1, 100),
            seg.latest_node_features_before(1, 100)
        );
    }

    #[test]
    fn mapped_segments_reject_corruption_like_the_heap_decoder() {
        if !crate::persist::mmap::supported() {
            return;
        }
        let mut bytes = encode_segment(&sample_segment());
        bytes[25] ^= 0x40;
        let path = seg_file("v2_corrupt.tgm", &bytes);
        let err = map_segment(&path).unwrap_err();
        assert!(matches!(err, TgmError::Persist(_)), "{err}");
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn manifest_round_trip() {
        let m = Manifest {
            num_nodes: 77,
            fixed_granularity: Some(TimeGranularity::Minute),
            static_feat_dim: 2,
            generation: 123,
            wal_epoch: 9,
            next_seq: 4,
            segments: vec![1, 2, 3],
            wal_records: 17,
        };
        let back = decode_manifest(&encode_manifest(&m)).unwrap();
        assert_eq!(back, m);
        let none = Manifest { fixed_granularity: None, ..m.clone() };
        let back = decode_manifest(&encode_manifest(&none)).unwrap();
        assert_eq!(back.fixed_granularity, None);
        // A pre-replication manifest (no trailing wal_records field)
        // still decodes, with the count defaulting to 0.
        let encoded = encode_manifest(&m);
        let (_, payload) = unframe(MANIFEST_MAGIC, &encoded, "manifest", FORMAT_VERSION).unwrap();
        let legacy = frame(MANIFEST_MAGIC, payload[..payload.len() - 8].to_vec());
        let back = decode_manifest(&legacy).unwrap();
        assert_eq!(back, Manifest { wal_records: 0, ..m });
    }

    #[test]
    fn static_feature_file_round_trips() {
        let feats = vec![0.5f32; 154];
        let (dim, back) = decode_static(&encode_static(2, &feats)).unwrap();
        assert_eq!(dim, 2);
        assert_eq!(back, feats);
        let (dim, back) = decode_static(&encode_static(0, &[])).unwrap();
        assert_eq!((dim, back.len()), (0, 0));
        // Torn/corrupt static files are typed errors.
        let mut bytes = encode_static(2, &feats);
        bytes.truncate(bytes.len() - 2);
        assert!(matches!(decode_static(&bytes).unwrap_err(), TgmError::Persist(_)));
    }

    #[test]
    fn atomic_write_round_trips_through_disk() {
        let dir = std::env::temp_dir().join("tgm_persist_format_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("MANIFEST");
        let m = Manifest {
            num_nodes: 3,
            fixed_granularity: None,
            static_feat_dim: 0,
            generation: 1,
            wal_epoch: 1,
            next_seq: 1,
            segments: vec![],
            wal_records: 0,
        };
        write_manifest(&path, &m).unwrap();
        assert_eq!(read_manifest(&path).unwrap(), m);
        // Overwrite atomically with new content.
        let m2 = Manifest { generation: 2, segments: vec![1], ..m };
        write_manifest(&path, &m2).unwrap();
        assert_eq!(read_manifest(&path).unwrap(), m2);
        // Missing file is a typed error.
        assert!(matches!(
            read_manifest(&dir.join("nope")).unwrap_err(),
            TgmError::Persist(_)
        ));
    }
}
