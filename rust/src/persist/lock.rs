//! Cross-process exclusive lock on a durable store directory.
//!
//! The [`crate::serving::TenantRouter`] already refuses two tenants
//! over one durable directory *in-process*, but nothing stopped a
//! second **process** from opening the same directory — two writers
//! would destroy each other's WAL. [`DirLock`] fences that with a
//! `LOCK` file held under an exclusive, kernel-managed `flock(2)`:
//!
//! * **Liveness is automatic.** The kernel releases the lock the moment
//!   the holding process exits — cleanly, by crash, or by SIGKILL — so
//!   a stale `LOCK` file left by a dead process never blocks recovery
//!   (no pid-file heuristics, no pid-recycling races).
//! * **Conflicts are diagnosable.** The holder writes its pid into the
//!   file; a refused acquisition reads it back for the error message.
//! * **The file is never deleted.** Removing it on drop would race a
//!   concurrent acquirer that already opened the old inode; leaving it
//!   in place is harmless (liveness lives in the kernel lock, not the
//!   file's existence) and recovery's garbage sweeps ignore it.
//!
//! Both `Durability::init` and [`crate::persist::recover`] acquire the
//! lock *before* touching the manifest, so init/recover races between
//! processes are excluded too.
//! On platforms without `flock` the lock degrades to O_EXCL creation
//! with removal on drop (best-effort; the unix path is the supported
//! deployment target).

use crate::error::{Result, TgmError};
use std::io::{Read, Seek, Write};
use std::path::{Path, PathBuf};

/// Lock file name inside a durable store directory.
pub const LOCK_FILE: &str = "LOCK";

/// Held exclusive lock on one durable directory. Released on drop (or
/// process death — the kernel owns the release).
pub struct DirLock {
    /// Keeping the handle open keeps the flock held (never read back;
    /// its close is the release).
    _file: std::fs::File,
    path: PathBuf,
    /// Non-flock fallback created the file exclusively and must remove
    /// it on drop (no kernel liveness on such platforms).
    remove_on_drop: bool,
}

#[cfg(unix)]
mod sys {
    use std::os::raw::c_int;

    pub const LOCK_EX: c_int = 2;
    pub const LOCK_NB: c_int = 4;

    extern "C" {
        pub fn flock(fd: c_int, operation: c_int) -> c_int;
    }
}

impl DirLock {
    /// Acquire the exclusive lock on `dir` (creating the directory and
    /// the `LOCK` file as needed). Typed [`TgmError::Persist`] when a
    /// live process — this one included — already holds it.
    #[cfg(unix)]
    pub fn acquire(dir: &Path) -> Result<DirLock> {
        use std::os::unix::io::AsRawFd;
        std::fs::create_dir_all(dir)?;
        let path = dir.join(LOCK_FILE);
        let mut file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(&path)?;
        let rc = unsafe { sys::flock(file.as_raw_fd(), sys::LOCK_EX | sys::LOCK_NB) };
        if rc != 0 {
            let err = std::io::Error::last_os_error();
            let mut holder = String::new();
            let _ = file.read_to_string(&mut holder);
            let holder = holder.trim();
            let holder = if holder.is_empty() { "unknown pid" } else { holder };
            return Err(TgmError::Persist(format!(
                "{} is locked by a live process ({holder}) — another store already \
                 holds this directory open ({err})",
                dir.display()
            )));
        }
        // Informational only (the kernel lock is the authority);
        // rewritten in place under the held lock.
        let _ = file.set_len(0);
        let _ = file.rewind();
        let _ = write!(file, "pid {}", std::process::id());
        Ok(DirLock { _file: file, path, remove_on_drop: false })
    }

    /// Non-flock fallback: exclusive creation, removed on drop. No
    /// liveness check is possible, so a leftover file from a crash must
    /// be removed by the operator (the error says so).
    #[cfg(not(unix))]
    pub fn acquire(dir: &Path) -> Result<DirLock> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(LOCK_FILE);
        match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
            Ok(mut file) => {
                let _ = write!(file, "pid {}", std::process::id());
                Ok(DirLock { _file: file, path, remove_on_drop: true })
            }
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                Err(TgmError::Persist(format!(
                    "{} has a LOCK file and this platform cannot check holder \
                     liveness — another store already holds this directory open, \
                     or a crashed one left the file behind (remove it manually)",
                    dir.display()
                )))
            }
            Err(e) => Err(e.into()),
        }
    }

    /// Path of the held lock file.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for DirLock {
    fn drop(&mut self) {
        // Unix: `file` closing releases the flock; the LOCK file stays
        // (deleting it would race a waiter holding the old inode).
        if self.remove_on_drop {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

impl std::fmt::Debug for DirLock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "DirLock({})", self.path.display())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tgm_dirlock_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn acquire_conflicts_and_releases_on_drop() {
        let dir = test_dir("conflict");
        let lock = DirLock::acquire(&dir).unwrap();
        assert!(lock.path().is_file());
        // flock conflicts apply between independent opens even within
        // one process, so the in-process double-acquire is refused too.
        let err = DirLock::acquire(&dir).unwrap_err();
        assert!(matches!(err, TgmError::Persist(_)), "{err}");
        assert!(err.to_string().contains("already holds"), "{err}");
        drop(lock);
        // Released: a fresh acquisition succeeds over the same file.
        let again = DirLock::acquire(&dir).unwrap();
        drop(again);
    }

    #[test]
    fn stale_lock_file_without_a_holder_is_acquirable() {
        let dir = test_dir("stale");
        std::fs::create_dir_all(&dir).unwrap();
        // A LOCK file with no live flock (e.g. left by a killed process;
        // here simply written by hand) must not block acquisition.
        std::fs::write(dir.join(LOCK_FILE), b"pid 999999").unwrap();
        let lock = DirLock::acquire(&dir);
        #[cfg(unix)]
        lock.unwrap();
        #[cfg(not(unix))]
        lock.unwrap_err(); // no liveness check without flock: refused
    }
}
