//! Error types for the TGM library.
//!
//! All fallible public APIs return [`Result<T>`](crate::Result) with
//! [`TgmError`]. Runtime (PJRT) errors from the `xla` crate are wrapped so
//! callers never need a direct `xla` dependency. The display/`Error`
//! plumbing is hand-written to keep the crate dependency-free offline.

/// Library-wide error type.
#[derive(Debug)]
pub enum TgmError {
    /// The requested time range or granularity is invalid.
    Time(String),

    /// A graph construction or query precondition was violated.
    Graph(String),

    /// A hook contract (requires/produces) could not be satisfied.
    Hook(String),

    /// A recipe's dependency graph is cyclic or has unmet requirements.
    Recipe(String),

    /// Batch attribute missing or of the wrong type/shape.
    Batch(String),

    /// An append into a segmented storage arrived older than the last
    /// sealed segment (streaming ingestion only accepts forward-in-time
    /// events once a segment has been sealed).
    StaleAppend(String),

    /// A writer outran a hard buffering limit (e.g. node events pending
    /// in an active segment with no edge to seal behind); the producer
    /// must seal/ingest edges or drop events before appending more.
    Backpressure(String),

    /// Multi-tenant serving error: unknown/duplicate tenant, or a tenant
    /// that has not published a snapshot yet.
    Serving(String),

    /// Durable-store failure: segment/WAL/manifest encode or decode,
    /// checksum mismatch, torn file, or a recovery-time invariant
    /// violation (see `crate::persist`).
    Persist(String),

    /// Replication failure: a replica could not bootstrap from or stay
    /// in sync with its primary (see `crate::replica`).
    Replica(String),

    /// Dataset loading / parsing failure.
    Io(String),

    /// Artifact manifest parsing or lookup failure.
    Manifest(String),

    /// PJRT / XLA runtime failure.
    Runtime(String),

    /// Model configuration / state mismatch.
    Model(String),

    /// Configuration error (CLI or experiment config).
    Config(String),
}

impl std::fmt::Display for TgmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TgmError::Time(m) => write!(f, "invalid time operation: {m}"),
            TgmError::Graph(m) => write!(f, "graph error: {m}"),
            TgmError::Hook(m) => write!(f, "hook error: {m}"),
            TgmError::Recipe(m) => write!(f, "recipe error: {m}"),
            TgmError::Batch(m) => write!(f, "batch error: {m}"),
            TgmError::StaleAppend(m) => write!(f, "stale append: {m}"),
            TgmError::Backpressure(m) => write!(f, "backpressure: {m}"),
            TgmError::Serving(m) => write!(f, "serving error: {m}"),
            TgmError::Persist(m) => write!(f, "persist error: {m}"),
            TgmError::Replica(m) => write!(f, "replica error: {m}"),
            TgmError::Io(m) => write!(f, "io error: {m}"),
            TgmError::Manifest(m) => write!(f, "manifest error: {m}"),
            TgmError::Runtime(m) => write!(f, "runtime error: {m}"),
            TgmError::Model(m) => write!(f, "model error: {m}"),
            TgmError::Config(m) => write!(f, "config error: {m}"),
        }
    }
}

impl std::error::Error for TgmError {}

impl From<std::io::Error> for TgmError {
    fn from(e: std::io::Error) -> Self {
        TgmError::Io(e.to_string())
    }
}

/// Convenient alias used across the crate.
pub type Result<T> = std::result::Result<T, TgmError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = TgmError::Graph("bad node id".into());
        assert!(e.to_string().contains("bad node id"));
        assert!(e.to_string().contains("graph"));
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "missing.csv");
        let e: TgmError = ioe.into();
        assert!(matches!(e, TgmError::Io(_)));
        assert!(e.to_string().contains("missing.csv"));
    }
}
