//! Error types for the TGM library.
//!
//! All fallible public APIs return [`Result<T>`](crate::Result) with
//! [`TgmError`]. Runtime (PJRT) errors from the `xla` crate are wrapped so
//! callers never need a direct `xla` dependency.

use thiserror::Error;

/// Library-wide error type.
#[derive(Debug, Error)]
pub enum TgmError {
    /// The requested time range or granularity is invalid.
    #[error("invalid time operation: {0}")]
    Time(String),

    /// A graph construction or query precondition was violated.
    #[error("graph error: {0}")]
    Graph(String),

    /// A hook contract (requires/produces) could not be satisfied.
    #[error("hook error: {0}")]
    Hook(String),

    /// A recipe's dependency graph is cyclic or has unmet requirements.
    #[error("recipe error: {0}")]
    Recipe(String),

    /// Batch attribute missing or of the wrong type/shape.
    #[error("batch error: {0}")]
    Batch(String),

    /// Dataset loading / parsing failure.
    #[error("io error: {0}")]
    Io(String),

    /// Artifact manifest parsing or lookup failure.
    #[error("manifest error: {0}")]
    Manifest(String),

    /// PJRT / XLA runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Model configuration / state mismatch.
    #[error("model error: {0}")]
    Model(String),

    /// Configuration error (CLI or experiment config).
    #[error("config error: {0}")]
    Config(String),
}

impl From<std::io::Error> for TgmError {
    fn from(e: std::io::Error) -> Self {
        TgmError::Io(e.to_string())
    }
}

/// Convenient alias used across the crate.
pub type Result<T> = std::result::Result<T, TgmError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = TgmError::Graph("bad node id".into());
        assert!(e.to_string().contains("bad node id"));
        assert!(e.to_string().contains("graph"));
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "missing.csv");
        let e: TgmError = ioe.into();
        assert!(matches!(e, TgmError::Io(_)));
        assert!(e.to_string().contains("missing.csv"));
    }
}
