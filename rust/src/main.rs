//! `tgm` — leader binary: train/evaluate models, run the paper's
//! research experiments (Tables 6/7/8/12), profile pipelines (Table 11),
//! and report memory (Table 10). Python is never invoked here; all model
//! compute goes through the AOT artifacts via PJRT.
//!
//! ```text
//! tgm stats      --dataset wiki --scale 0.4
//! tgm train      --model tgat_link --dataset wiki --scale 0.4 --epochs 3
//! tgm discretize --dataset lastfm --scale 0.5 [--baseline true]
//! tgm profile    --model tgat_link --dataset wiki --scale 0.2
//! tgm memory
//! tgm exp granularity|graphprop|batchsize|correctness [--scale S]
//! ```

use std::collections::HashMap;

use tgm::coordinator::{
    evaluate_edgebank, evaluate_persistent_graph, Pipeline, PipelineConfig, Split,
};
use tgm::graph::{discretize, discretize_utg, ReduceOp, Task};
use tgm::hooks::SamplerKind;
use tgm::io::gen;
use tgm::loader::BatchBy;
use tgm::models::EdgeBankMode;
use tgm::runtime::XlaEngine;
use tgm::util::TimeGranularity;
use tgm::{Result, TgmError};

/// Minimal flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".into());
                    i += 1;
                }
            } else {
                i += 1;
            }
        }
        Args { flags }
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn usize(&self, key: &str, default: usize) -> usize {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn bool(&self, key: &str) -> bool {
        self.flags.get(key).map(|v| v == "true").unwrap_or(false)
    }
}

fn engine() -> Result<XlaEngine> {
    let dir = std::env::var("TGM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    XlaEngine::cpu(dir)
}

fn pipeline_cfg(model: &str, args: &Args) -> Result<PipelineConfig> {
    let mut cfg = PipelineConfig::new(model);
    cfg.sampler = match args.get("sampler", "recency").as_str() {
        "recency" => SamplerKind::Recency,
        "uniform" => SamplerKind::Uniform,
        "naive" => SamplerKind::Naive,
        other => return Err(TgmError::Config(format!("unknown sampler `{other}`"))),
    };
    cfg.granularity = TimeGranularity::parse(&args.get("granularity", "day"))?;
    cfg.seed = args.usize("seed", 0) as u64;
    Ok(cfg)
}

fn cmd_stats(args: &Args) -> Result<()> {
    let data = gen::by_name(&args.get("dataset", "wiki"), args.f64("scale", 0.4), 42)?;
    println!("{}", data.stats());
    let s = data.split()?;
    println!(
        "splits: train={} val={} test={}",
        s.train.num_edges(),
        s.val.num_edges(),
        s.test.num_edges()
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let eng = engine()?;
    let model = args.get("model", "tpnet_link");
    let data = gen::by_name(&args.get("dataset", "wiki"), args.f64("scale", 0.4), 42)?;
    let mut pipe = Pipeline::new(&eng, data, pipeline_cfg(&model, args)?)?;
    let epochs = args.usize("epochs", 3);
    for e in 0..epochs {
        let r = pipe.train_epoch()?;
        println!("epoch {e}: loss={:.4} batches={} {:.2}s", r.mean_loss, r.batches, r.seconds);
    }
    let fmt = |r: &tgm::coordinator::EvalReport| {
        r.mrr
            .map(|m| format!("MRR={m:.4}"))
            .or(r.ndcg.map(|n| format!("NDCG@10={n:.4}")))
            .or(r.auc.map(|a| format!("AUC={a:.4}")))
            .unwrap_or_default()
    };
    let val = pipe.evaluate(Split::Val)?;
    let test = pipe.evaluate(Split::Test)?;
    println!(
        "val {} ({} queries) | test {} ({} queries)",
        fmt(&val),
        val.queries,
        fmt(&test),
        test.queries
    );
    Ok(())
}

fn cmd_discretize(args: &Args) -> Result<()> {
    let data = gen::by_name(&args.get("dataset", "lastfm"), args.f64("scale", 0.5), 42)?;
    let g = TimeGranularity::parse(&args.get("granularity", "hour"))?;
    let storage = data.storage();
    let t0 = std::time::Instant::now();
    let out = if args.bool("baseline") {
        discretize_utg(storage, g, ReduceOp::Count)?
    } else {
        discretize(storage, g, ReduceOp::Count)?
    };
    let dt = t0.elapsed();
    println!(
        "{} ({} edges) -> {} snapshot edges at {} in {:.4}s ({})",
        data.name(),
        storage.num_edges(),
        out.num_edges(),
        g.as_str(),
        dt.as_secs_f64(),
        if args.bool("baseline") { "UTG baseline" } else { "TGM vectorized" }
    );
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let eng = engine()?;
    let model = args.get("model", "tgat_link");
    let data = gen::by_name(&args.get("dataset", "wiki"), args.f64("scale", 0.2), 42)?;
    let mut pipe = Pipeline::new(&eng, data, pipeline_cfg(&model, args)?)?;
    pipe.profiler.start_wall();
    let r = pipe.train_epoch()?;
    println!("{model}: loss={:.4} over {} batches\n", r.mean_loss, r.batches);
    println!("{}", pipe.profiler);
    Ok(())
}

fn cmd_memory(_args: &Args) -> Result<()> {
    let eng = engine()?;
    let manifest = eng.manifest();
    println!("{:<18} {:>12} {:>10}", "model", "state (MB)", "tensors");
    let mut names: Vec<&String> = manifest.models.keys().collect();
    names.sort();
    for name in names {
        let spec = &manifest.models[name];
        println!(
            "{:<18} {:>12.2} {:>10}",
            name,
            spec.state_bytes() as f64 / 1e6,
            spec.state_shapes.len()
        );
    }
    Ok(())
}

/// Table 6 / RQ2: snapshot granularity vs DTDG link MRR.
fn exp_granularity(args: &Args) -> Result<()> {
    let eng = engine()?;
    let scale = args.f64("scale", 0.25);
    let epochs = args.usize("epochs", 3);
    println!("RQ2 (Table 6): snapshot granularity vs link MRR");
    println!("{:<10} {:<12} {:<8} {:>8}", "dataset", "model", "gran", "MRR");
    for ds in ["wiki", "reddit"] {
        for model in ["gcn_link", "tgcn_link", "gclstm_link"] {
            for gran in [TimeGranularity::Hour, TimeGranularity::Day, TimeGranularity::Week] {
                let data = gen::by_name(ds, scale, 42)?;
                let mut cfg = PipelineConfig::new(model);
                cfg.granularity = gran;
                let mut pipe = Pipeline::new(&eng, data, cfg)?;
                for _ in 0..epochs {
                    pipe.train_epoch()?;
                }
                let r = pipe.evaluate(Split::Test)?;
                println!(
                    "{:<10} {:<12} {:<8} {:>8.4}",
                    ds,
                    model,
                    gran.as_str(),
                    r.mrr.unwrap_or(0.0)
                );
            }
        }
    }
    Ok(())
}

/// Table 7 / RQ1: graph growth prediction AUC.
fn exp_graphprop(args: &Args) -> Result<()> {
    let eng = engine()?;
    let scale = args.f64("scale", 0.25);
    let epochs = args.usize("epochs", 3);
    println!("RQ1 (Table 7): next-snapshot growth AUC (daily snapshots)");
    println!("{:<10} {:<14} {:>8}", "dataset", "model", "AUC");
    for ds in ["wiki", "reddit"] {
        // Persistent-forecast baseline.
        let data = gen::by_name(ds, scale, 42)?;
        let splits = data.split()?;
        let pf = evaluate_persistent_graph(&splits.test, TimeGranularity::Day)?;
        println!("{:<10} {:<14} {:>8.4}", ds, "P.F.", pf.auc.unwrap_or(0.5));
        for model in ["tgcn_graph", "gclstm_graph", "gcn_graph"] {
            let raw = gen::by_name(ds, scale, 42)?;
            // DTDG substrate: hourly-discretized view, graph task tag.
            let data = tgm::graph::DGData::new(
                discretize(raw.storage(), TimeGranularity::Hour, ReduceOp::Count)?,
                ds,
                Task::GraphProperty,
            );
            let mut cfg = PipelineConfig::new(model);
            cfg.granularity = TimeGranularity::Day;
            let mut pipe = Pipeline::new(&eng, data, cfg)?;
            for _ in 0..epochs {
                pipe.train_epoch()?;
            }
            let r = pipe.evaluate(Split::Test)?;
            println!("{:<10} {:<14} {:>8.4}", ds, model, r.auc.unwrap_or(0.5));
        }
    }
    Ok(())
}

/// Table 8 / RQ3: validation batch size & unit vs link MRR.
fn exp_batchsize(args: &Args) -> Result<()> {
    let eng = engine()?;
    let scale = args.f64("scale", 0.2);
    let epochs = args.usize("epochs", 2);
    let model = args.get("model", "tpnet_link");
    println!("RQ3 (Table 8): eval batching vs link MRR ({model}, wiki)");
    let data = gen::by_name("wiki", scale, 42)?;
    let mut pipe = Pipeline::new(&eng, data, PipelineConfig::new(&model))?;
    for _ in 0..epochs {
        pipe.train_epoch()?;
    }
    println!("{:<16} {:>8}", "batching", "MRR");
    for bs in [50usize, 100, 200] {
        let r = pipe.evaluate_link_with(Split::Test, BatchBy::Events(bs))?;
        println!("{:<16} {:>8.4}", format!("size {bs}"), r.mrr.unwrap_or(0.0));
    }
    for unit in [TimeGranularity::Hour, TimeGranularity::Day] {
        let r = pipe.evaluate_link_with(Split::Test, BatchBy::Time(unit))?;
        println!("{:<16} {:>8.4}", format!("unit {}", unit.as_str()), r.mrr.unwrap_or(0.0));
    }
    Ok(())
}

/// Table 12: correctness sweep over the model zoo.
fn exp_correctness(args: &Args) -> Result<()> {
    let eng = engine()?;
    let scale = args.f64("scale", 0.2);
    let epochs = args.usize("epochs", 2);
    println!("Table 12: model zoo on wiki (link MRR) and trade (node NDCG@10)");
    println!("{:<16} {:<8} {:>10} {:>10}", "model", "task", "val", "test");

    let wiki = gen::by_name("wiki", scale, 42)?;
    let splits = wiki.split()?;
    let eb = evaluate_edgebank(&wiki, &splits.val, EdgeBankMode::Unlimited, 10, 0)?;
    let ebt = evaluate_edgebank(&wiki, &splits.test, EdgeBankMode::Unlimited, 10, 0)?;
    let ranked_mrr = |r: &tgm::coordinator::EvalReport, split: &str| -> Result<f64> {
        r.mrr.ok_or_else(|| {
            TgmError::Model(format!("edgebank evaluator returned no ranked edges on {split}"))
        })
    };
    println!(
        "{:<16} {:<8} {:>10.4} {:>10.4}",
        "edgebank",
        "link",
        ranked_mrr(&eb, "val")?,
        ranked_mrr(&ebt, "test")?
    );

    for model in [
        "tpnet_link",
        "tgn_link",
        "graphmixer_link",
        "tgat_link",
        "dygformer_link",
        "gcn_link",
        "gclstm_link",
        "tgcn_link",
    ] {
        let mut cfg = PipelineConfig::new(model);
        cfg.granularity = TimeGranularity::Day;
        let mut pipe = Pipeline::new(&eng, wiki.clone(), cfg)?;
        for _ in 0..epochs {
            pipe.train_epoch()?;
        }
        let v = pipe.evaluate(Split::Val)?;
        let t = pipe.evaluate(Split::Test)?;
        println!(
            "{:<16} {:<8} {:>10.4} {:>10.4}",
            model,
            "link",
            v.mrr.unwrap_or(0.0),
            t.mrr.unwrap_or(0.0)
        );
    }

    let trade = gen::by_name("trade", args.f64("trade-scale", 0.5), 42)?;
    for model in ["tgn_node", "dygformer_node", "gcn_node", "gclstm_node", "tgcn_node"] {
        let mut cfg = PipelineConfig::new(model);
        cfg.granularity = TimeGranularity::Year;
        let mut pipe = Pipeline::new(&eng, trade.clone(), cfg)?;
        for _ in 0..epochs {
            pipe.train_epoch()?;
        }
        let v = pipe.evaluate(Split::Val)?;
        let t = pipe.evaluate(Split::Test)?;
        println!(
            "{:<16} {:<8} {:>10.4} {:>10.4}",
            model,
            "node",
            v.ndcg.unwrap_or(0.0),
            t.ndcg.unwrap_or(0.0)
        );
    }
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    let args = Args::parse(&argv[1.min(argv.len())..]);
    let result = match cmd {
        "stats" => cmd_stats(&args),
        "train" => cmd_train(&args),
        "discretize" => cmd_discretize(&args),
        "profile" => cmd_profile(&args),
        "memory" => cmd_memory(&args),
        "exp" => match argv.get(1).map(String::as_str) {
            Some("granularity") => exp_granularity(&args),
            Some("graphprop") => exp_graphprop(&args),
            Some("batchsize") => exp_batchsize(&args),
            Some("correctness") => exp_correctness(&args),
            other => Err(TgmError::Config(format!("unknown experiment {other:?}"))),
        },
        "help" | "--help" | "-h" => {
            println!(
                "tgm <stats|train|discretize|profile|memory|exp> [--flags]\n\
                 experiments: exp granularity | graphprop | batchsize | correctness"
            );
            Ok(())
        }
        other => Err(TgmError::Config(format!("unknown command `{other}`"))),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
