//! Replication transport: how a replica reads its primary's durable
//! state.
//!
//! [`ReplicationLog`] is the full surface a replica needs — manifest,
//! sealed-segment bytes, the write-once static table, and an
//! offset-addressed WAL tail. Every method is a pull (the replica
//! polls), every payload is already checksummed by the on-disk format,
//! and the WAL tail carries the epoch fence, so the trait ports to a
//! socket transport without protocol changes: a server would answer the
//! same four requests over the wire.
//!
//! [`DirTransport`] is the local-dir implementation: the replica reads
//! the primary's directory directly. It never takes the primary's
//! `LOCK` — the primary keeps running — and relies on the store's
//! write protocol instead: segment files are write-once and synced
//! before the manifest references them, the manifest is replaced by
//! rename (a read sees the old or the new one, never a blend), and the
//! WAL is append-only within an epoch.

use crate::error::{Result, TgmError};
use crate::persist::wal::{read_wal_tail, WalTail, HEADER_LEN};
use crate::persist::{format, segment_path, Manifest, MANIFEST_FILE, STATIC_FILE, WAL_FILE};
use std::path::{Path, PathBuf};

/// Pull-based view of a primary's replicated state (see module docs).
pub trait ReplicationLog: Send + Sync {
    /// The primary's current manifest (its acknowledged sealed state).
    fn manifest(&self) -> Result<Manifest>;

    /// Raw bytes of sealed segment `seq`. Segment files are immutable
    /// and never reuse a seq, so the response is cacheable forever.
    fn fetch_segment(&self, seq: u64) -> Result<Vec<u8>>;

    /// Raw bytes of the write-once static-feature table.
    fn fetch_static(&self) -> Result<Vec<u8>>;

    /// Complete WAL records at `expected_epoch` from byte `offset`.
    /// An epoch mismatch is a fence, not an error: the reply names the
    /// observed epoch, delivers nothing, and leaves the cursor where it
    /// was (see [`read_wal_tail`]).
    fn wal_tail(&self, expected_epoch: u64, offset: usize) -> Result<WalTail>;
}

/// [`ReplicationLog`] over a locally readable primary directory (same
/// machine or a shared filesystem).
pub struct DirTransport {
    dir: PathBuf,
}

impl DirTransport {
    /// Transport reading the primary's durable dir in place.
    pub fn new(dir: impl Into<PathBuf>) -> DirTransport {
        DirTransport { dir: dir.into() }
    }

    /// The primary directory this transport reads.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

impl ReplicationLog for DirTransport {
    fn manifest(&self) -> Result<Manifest> {
        format::read_manifest(&self.dir.join(MANIFEST_FILE))
    }

    fn fetch_segment(&self, seq: u64) -> Result<Vec<u8>> {
        let path = segment_path(&self.dir, seq);
        std::fs::read(&path).map_err(|e| {
            TgmError::Replica(format!("cannot fetch segment {}: {e}", path.display()))
        })
    }

    fn fetch_static(&self) -> Result<Vec<u8>> {
        let path = self.dir.join(STATIC_FILE);
        std::fs::read(&path).map_err(|e| {
            TgmError::Replica(format!("cannot fetch static table {}: {e}", path.display()))
        })
    }

    fn wal_tail(&self, expected_epoch: u64, offset: usize) -> Result<WalTail> {
        let path = self.dir.join(WAL_FILE);
        if !path.exists() {
            // Only legitimate before the primary's first append (epoch
            // 1, nothing to deliver); the poll loop validates epochs
            // against the manifest, so a vanished log at a later epoch
            // surfaces as a stall, not silent data loss.
            return Ok(WalTail {
                epoch: expected_epoch,
                events: Vec::new(),
                end_offset: offset.max(HEADER_LEN),
                torn_tail: false,
            });
        }
        read_wal_tail(&path, expected_epoch, offset)
    }
}
