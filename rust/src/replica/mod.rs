//! Replicated serving tier: WAL-tailing read replicas.
//!
//! A [`Replica`] mirrors one primary [`SegmentedStorage`]'s durable
//! state and serves reads from it, scaling read throughput
//! horizontally: every replica publishes the same generation-pinned
//! [`StorageSnapshot`]s the primary would, through its own
//! [`SnapshotCell`], and the serving layer fans point queries out
//! across them (`crate::serving::ReadHandle`).
//!
//! ## Protocol
//!
//! **Bootstrap.** The replica copies the primary's `MANIFEST`-referenced
//! sealed segment files (plus the write-once static table) through a
//! [`ReplicationLog`] into a replica-local directory, opens them
//! mmap-backed, and rebuilds the store exactly the way crash recovery
//! does. Local files are named by the primary's never-reused segment
//! seq, so a restarted replica revalidates its cache and fetches only
//! what it is missing — bootstrap bytes are never re-shipped. The
//! replica never touches the primary's flock-held `LOCK`; it holds its
//! **own** lock on the replica directory instead, and relies on the
//! store's write protocol (write-once synced segments, rename-replaced
//! manifest, append-only WAL epochs) for consistent reads of a live
//! primary.
//!
//! **Tailing.** Each poll round reads the manifest, reconciles the
//! sealed stack (appended seqs install as seals; replaced contiguous
//! runs install as compaction deltas through
//! [`SegmentedStorage::install_compacted`] — a merged file ships once,
//! old bytes never re-ship), then reads the WAL tail from a byte
//! cursor. The WAL's epoch header **fences** the tail: a record is
//! only applied when its epoch matches the manifest the round started
//! from, so a seal racing the poll can never double-apply tail events
//! that are already inside the sealed segment it just installed.
//!
//! **Generations.** The manifest anchors the epoch-start generation
//! (`generation - wal_records`), and each applied tail record advances
//! it by one — the identical arithmetic crash recovery uses — so a
//! replica snapshot at generation *G* holds byte-for-byte the state
//! the primary published at *G*. A round publishes only once it has
//! caught up to the manifest's own record count (the transport reads
//! the manifest *before* the WAL, so the tail always spans it).
//!
//! Metrics: `tgm_replica_lag_us`, `tgm_replica_applied_generation`,
//! `tgm_replica_bootstrap_duration_us`, plus shipped-byte / applied /
//! resync counters, all labeled per replica and scrapeable through the
//! `/metrics` endpoint (`crate::obs::export`).

pub mod log;

pub use log::{DirTransport, ReplicationLog};

use crate::error::{Result, TgmError};
use crate::graph::{GraphStorage, SegmentedStorage, SnapshotCell, StorageSnapshot};
use crate::obs::{self, Counter, Gauge, Label};
use crate::persist::{self, format, segment_path, DirLock, Manifest, SegmentBacking, STATIC_FILE};
use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// How a replica stores and serves its mirrored state.
#[derive(Debug, Clone)]
pub struct ReplicaConfig {
    /// Replica-local directory caching fetched segment files (named by
    /// primary seq). Locked by the replica; must not be the primary's
    /// directory.
    pub dir: PathBuf,
    /// Backing for fetched segment files (mmap by default — replicas
    /// serve straight from the page cache).
    pub backing: SegmentBacking,
    /// How often the background tailer polls the primary.
    pub poll_interval: Duration,
}

impl ReplicaConfig {
    /// Defaults: mmap-backed segments, 10 ms poll cadence.
    pub fn new(dir: impl Into<PathBuf>) -> ReplicaConfig {
        ReplicaConfig {
            dir: dir.into(),
            backing: SegmentBacking::Mmap,
            poll_interval: Duration::from_millis(10),
        }
    }

    /// Set the sealed-segment backing.
    pub fn with_backing(mut self, backing: SegmentBacking) -> ReplicaConfig {
        self.backing = backing;
        self
    }

    /// Set the background tailer's poll cadence.
    pub fn with_poll_interval(mut self, interval: Duration) -> ReplicaConfig {
        self.poll_interval = interval;
        self
    }
}

/// What bootstrap found and moved (returned by [`Replica::bootstrap`]).
#[derive(Debug, Default, Clone)]
pub struct BootstrapReport {
    /// Sealed segments behind the replica after catch-up.
    pub segments: usize,
    /// Locally cached segment files revalidated instead of shipped (a
    /// restarted replica re-fetches only what it is missing).
    pub reused_segments: usize,
    /// Bytes fetched from the primary (segments + static table).
    pub shipped_bytes: u64,
    /// WAL-tail events replayed during catch-up.
    pub replayed_events: usize,
    /// Applied generation after catch-up (0 when the primary is empty).
    pub generation: u64,
    /// Wall-clock bootstrap duration.
    pub duration_us: u64,
}

/// What one poll round did (returned by [`Replica::poll`]).
#[derive(Debug, Default, Clone, Copy)]
pub struct PollOutcome {
    /// A caught-up snapshot was (re)published this round. `false` when
    /// the WAL fence tripped (a seal raced the round — the next round
    /// converges) or the primary has no events yet.
    pub published: bool,
    /// WAL-tail events applied this round.
    pub applied_events: usize,
    /// Sealed segments installed this round (seals + compaction deltas).
    pub installed_segments: usize,
    /// The round fell back to a wholesale stack rebuild (still reusing
    /// every locally cached file).
    pub resynced: bool,
}

/// Replica-side counters shared with serving handles while the
/// [`Replica`] itself lives on its tailer thread.
#[derive(Debug, Default)]
pub struct ReplicaShared {
    applied_generation: AtomicU64,
    /// Round-start µs of the last caught-up round: everything the
    /// primary acknowledged before this instant is applied here.
    fresh_as_of_us: AtomicU64,
    shipped_bytes: AtomicU64,
    resyncs: AtomicU64,
}

impl ReplicaShared {
    /// Generation of the replica's latest caught-up state.
    pub fn applied_generation(&self) -> u64 {
        self.applied_generation.load(Ordering::Relaxed)
    }

    /// Upper bound on staleness: µs since the last caught-up round
    /// began (`None` before the first). Everything the primary
    /// acknowledged earlier than that instant is already applied.
    pub fn lag_us(&self) -> Option<u64> {
        let t = self.fresh_as_of_us.load(Ordering::Relaxed);
        if t == 0 {
            return None;
        }
        Some(obs::trace::now_us().saturating_sub(t))
    }

    /// Cumulative bytes fetched from the primary.
    pub fn shipped_bytes(&self) -> u64 {
        self.shipped_bytes.load(Ordering::Relaxed)
    }

    /// Wholesale resyncs taken (anomalous manifest diffs; normally 0).
    pub fn resyncs(&self) -> u64 {
        self.resyncs.load(Ordering::Relaxed)
    }
}

/// One WAL-tailing replica of a primary durable store (see module
/// docs). Drive it manually with [`Replica::poll`] or hand it to a
/// background thread with [`Replica::spawn_tailer`].
pub struct Replica {
    name: String,
    log: Arc<dyn ReplicationLog>,
    dir: PathBuf,
    backing: SegmentBacking,
    _lock: DirLock,
    store: SegmentedStorage,
    cell: SnapshotCell,
    /// The primary's write-once static table (kept for resync rebuilds).
    static_feats: Vec<f32>,
    /// Primary segment seqs mirrored by the store's sealed stack, in
    /// order (the reconcile diff runs against this).
    seqs: Vec<u64>,
    /// WAL epoch the tail cursor is valid for.
    epoch: u64,
    /// Byte cursor into the primary's WAL (complete records only).
    wal_offset: usize,
    /// Records applied in the current epoch (the generation formula's
    /// `k`; resets when the epoch advances).
    applied_epoch_records: u64,
    reused_segments: usize,
    applied_events_total: u64,
    shared: Arc<ReplicaShared>,
    lag_gauge: Gauge,
    applied_gauge: Gauge,
    shipped_ctr: Counter,
    applied_events_ctr: Counter,
    installed_segments_ctr: Counter,
    resync_ctr: Counter,
    poll_errors_ctr: Counter,
}

/// Rounds bootstrap retries before giving up (each retry re-reads the
/// manifest, so races with primary seals/compactions converge fast).
const BOOTSTRAP_ROUNDS: usize = 8;

impl Replica {
    /// Bootstrap a replica of the primary behind `log` into
    /// `cfg.dir`, catch up, and publish the first snapshot (unless the
    /// primary is still empty). `name` labels this replica's metrics
    /// and serving identity.
    pub fn bootstrap(
        name: impl Into<String>,
        log: Arc<dyn ReplicationLog>,
        cfg: ReplicaConfig,
    ) -> Result<(Replica, BootstrapReport)> {
        let name = name.into();
        let start = obs::trace::now_us();
        let mut span = obs::span("replica", "bootstrap").with_detail(name.clone());
        std::fs::create_dir_all(&cfg.dir).map_err(|e| {
            TgmError::Replica(format!("cannot create replica dir {}: {e}", cfg.dir.display()))
        })?;
        let lock = DirLock::acquire(&cfg.dir)?;

        let label = Label::from(name.clone());
        let registry = obs::registry();
        let shared = Arc::new(ReplicaShared::default());

        let man = log.manifest()?;
        let (static_feats, static_shipped) = fetch_static_cached(log.as_ref(), &cfg.dir, &man)?;
        shared.shipped_bytes.fetch_add(static_shipped, Ordering::Relaxed);
        let shipped_ctr =
            registry.counter("tgm_replica_shipped_bytes_total", &[("replica", label.clone())]);
        shipped_ctr.add(static_shipped);

        let mut replica = Replica {
            store: SegmentedStorage::from_replica_parts(
                man.num_nodes,
                man.fixed_granularity,
                man.static_feat_dim,
                static_feats.clone(),
                Vec::new(),
                0,
            ),
            cell: SnapshotCell::new(),
            static_feats,
            seqs: Vec::new(),
            epoch: man.wal_epoch,
            wal_offset: 0,
            applied_epoch_records: 0,
            reused_segments: 0,
            applied_events_total: 0,
            shared: Arc::clone(&shared),
            lag_gauge: registry.gauge("tgm_replica_lag_us", &[("replica", label.clone())]),
            applied_gauge: registry
                .gauge("tgm_replica_applied_generation", &[("replica", label.clone())]),
            shipped_ctr,
            applied_events_ctr: registry
                .counter("tgm_replica_applied_events_total", &[("replica", label.clone())]),
            installed_segments_ctr: registry
                .counter("tgm_replica_installed_segments_total", &[("replica", label.clone())]),
            resync_ctr: registry
                .counter("tgm_replica_resyncs_total", &[("replica", label.clone())]),
            poll_errors_ctr: registry
                .counter("tgm_replica_poll_errors_total", &[("replica", label.clone())]),
            name,
            log,
            dir: cfg.dir,
            backing: cfg.backing,
            _lock: lock,
        };

        // Catch up. A round can race a primary seal (WAL fence) or
        // compaction (segment file vanishing between manifest read and
        // fetch); both converge on the next round's fresh manifest.
        let mut last_err: Option<TgmError> = None;
        for _ in 0..BOOTSTRAP_ROUNDS {
            match replica.poll() {
                Ok(outcome) => {
                    last_err = None;
                    if outcome.published || replica.store.total_edges() == 0 {
                        break;
                    }
                }
                Err(e) => last_err = Some(e),
            }
        }
        if let Some(e) = last_err {
            return Err(e);
        }

        let report = BootstrapReport {
            segments: replica.seqs.len(),
            reused_segments: replica.reused_segments,
            shipped_bytes: replica.shared.shipped_bytes(),
            replayed_events: replica.applied_events_total as usize,
            generation: replica.shared.applied_generation(),
            duration_us: obs::trace::now_us().saturating_sub(start),
        };
        span.set_detail(format!(
            "{} segments={} reused={} shipped_bytes={} replayed={} generation={}",
            replica.name,
            report.segments,
            report.reused_segments,
            report.shipped_bytes,
            report.replayed_events,
            report.generation
        ));
        drop(span);
        registry
            .histogram("tgm_replica_bootstrap_duration_us", &[("replica", label)])
            .record_us(report.duration_us);
        Ok((replica, report))
    }

    /// One catch-up round: reconcile the sealed stack against the
    /// primary's manifest, apply the WAL tail behind the epoch fence,
    /// and republish if caught up (see module docs). Safe to call at
    /// any cadence; an error leaves the replica consistent and the next
    /// round retries from the cursor.
    pub fn poll(&mut self) -> Result<PollOutcome> {
        let round_start = obs::trace::now_us();
        let mut outcome = PollOutcome::default();
        let m = self.log.manifest()?;
        if m.num_nodes != self.store.num_nodes() {
            return Err(TgmError::Replica(format!(
                "primary changed num_nodes from {} to {} under replica `{}`",
                self.store.num_nodes(),
                m.num_nodes,
                self.name
            )));
        }
        if m.wal_epoch < self.epoch {
            return Err(TgmError::Replica(format!(
                "primary wal epoch went backwards ({} -> {}) under replica `{}`",
                self.epoch, m.wal_epoch, self.name
            )));
        }
        if m.wal_epoch > self.epoch {
            // The primary sealed: every tail event we replayed this
            // epoch is inside a segment the reconcile below installs.
            self.store.replica_clear_tail();
            self.epoch = m.wal_epoch;
            self.wal_offset = 0;
            self.applied_epoch_records = 0;
        }
        if m.segments != self.seqs {
            self.reconcile(&m, &mut outcome)?;
        }
        if outcome.installed_segments > 0 || outcome.resynced {
            persist::sweep_unreferenced_segments(&self.dir, &self.seqs);
        }

        let tail = self.log.wal_tail(self.epoch, self.wal_offset)?;
        if tail.epoch != self.epoch {
            if tail.epoch < self.epoch {
                return Err(TgmError::Replica(format!(
                    "primary wal epoch went backwards ({} -> {}) under replica `{}`",
                    self.epoch, tail.epoch, self.name
                )));
            }
            // Fenced: the primary sealed after this round's manifest
            // read. Nothing is applied (the records we hold cursors for
            // are inside a segment the next round installs), and this
            // round must not publish — its generation arithmetic spans
            // the seal.
            return Ok(outcome);
        }
        let n = tail.events.len();
        for ev in tail.events {
            if let Err(e) = self.store.replay_append(ev) {
                // The cursor no longer matches what was applied; a
                // wholesale rebuild from the (all-durable) manifest
                // restores consistency before surfacing the error.
                self.resync(&m, &mut outcome)?;
                return Err(e);
            }
        }
        self.wal_offset = tail.end_offset;
        self.applied_epoch_records += n as u64;
        self.applied_events_total += n as u64;
        self.applied_events_ctr.add(n as u64);
        outcome.applied_events = n;

        // Publish only when caught up past the manifest's own record
        // count: the transport reads the manifest before the WAL, so a
        // complete tail always spans it — falling short means a torn
        // in-flight record cut the read early; retry next round.
        if self.applied_epoch_records >= m.wal_records {
            let anchor = m.generation.saturating_sub(m.wal_records);
            let generation = anchor + self.applied_epoch_records;
            self.store.set_replica_generation(generation);
            if self.store.total_edges() > 0 {
                self.store.publish_to(&self.cell)?;
                outcome.published = true;
            }
            self.shared.applied_generation.store(generation, Ordering::Relaxed);
            self.shared.fresh_as_of_us.store(round_start.max(1), Ordering::Relaxed);
            self.applied_gauge.set(generation.min(i64::MAX as u64) as i64);
            let lag = obs::trace::now_us().saturating_sub(round_start);
            self.lag_gauge.set(lag.min(i64::MAX as u64) as i64);
        }
        Ok(outcome)
    }

    /// Diff the local seq stack against the manifest's and apply the
    /// difference: appended seqs install as seals, contiguous replaced
    /// runs install as compaction deltas (one merged file ships; the
    /// run's old bytes never re-ship). Any shape the two moves cannot
    /// explain falls back to [`Replica::resync`].
    fn reconcile(&mut self, m: &Manifest, outcome: &mut PollOutcome) -> Result<()> {
        let mset: HashSet<u64> = m.segments.iter().copied().collect();
        let mut i = 0usize;
        let mut j = 0usize;
        loop {
            let local = self.seqs.get(i).copied();
            let remote = m.segments.get(j).copied();
            match (local, remote) {
                (None, None) => break,
                (Some(l), Some(r)) if l == r => {
                    i += 1;
                    j += 1;
                }
                _ => {
                    // Maximal run of local seqs the manifest dropped.
                    let mut k = i;
                    while k < self.seqs.len() && !mset.contains(&self.seqs[k]) {
                        k += 1;
                    }
                    if k > i {
                        // Replaced run: a compaction delta addressed by
                        // the new merged seq.
                        let Some(seq) = remote else {
                            return self.resync(m, outcome);
                        };
                        if self.seqs.contains(&seq) {
                            return self.resync(m, outcome);
                        }
                        let merged = self.fetch_local_segment(seq, m.num_nodes)?;
                        let (_, ids) = self.store.sealed_segments();
                        let replaced = ids[i..k].to_vec();
                        if replaced.len() < 2
                            || !self.store.install_compacted(merged, &replaced, None)?
                        {
                            // A merged run folding a seal this replica
                            // never saw individually (seal + compaction
                            // between two polls) — rebuild wholesale,
                            // still reusing every cached file.
                            return self.resync(m, outcome);
                        }
                        self.seqs.splice(i..k, [seq]);
                        self.store.replica_recompute_sealed_invariants();
                        self.installed_segments_ctr.inc();
                        outcome.installed_segments += 1;
                        i += 1;
                        j += 1;
                    } else if local.is_none() {
                        // Appended seal.
                        let Some(seq) = remote else {
                            return self.resync(m, outcome);
                        };
                        let seg = self.fetch_local_segment(seq, m.num_nodes)?;
                        self.store.replica_install_sealed(Arc::new(seg));
                        self.seqs.push(seq);
                        self.installed_segments_ctr.inc();
                        outcome.installed_segments += 1;
                        i += 1;
                        j += 1;
                    } else {
                        // A local seq the manifest still holds, out of
                        // position — nothing the protocol produces.
                        return self.resync(m, outcome);
                    }
                }
            }
        }
        Ok(())
    }

    /// Rebuild the sealed stack wholesale from the manifest. The
    /// anomaly escape hatch: correctness never depends on the diff in
    /// [`Replica::reconcile`] staying two-move-shaped. Every locally
    /// cached file is revalidated and reused, so even this path ships
    /// only segments the replica has never held.
    fn resync(&mut self, m: &Manifest, outcome: &mut PollOutcome) -> Result<()> {
        let mut sealed = Vec::with_capacity(m.segments.len());
        for &seq in &m.segments {
            sealed.push(Arc::new(self.fetch_local_segment(seq, m.num_nodes)?));
        }
        for w in sealed.windows(2) {
            if w[1].start_time() < w[0].end_time() {
                return Err(TgmError::Replica(
                    "primary manifest orders segments with overlapping time spans".into(),
                ));
            }
        }
        self.store = SegmentedStorage::from_replica_parts(
            m.num_nodes,
            m.fixed_granularity,
            m.static_feat_dim,
            self.static_feats.clone(),
            sealed,
            m.generation.saturating_sub(m.wal_records),
        );
        self.seqs = m.segments.clone();
        self.epoch = m.wal_epoch;
        self.wal_offset = 0;
        self.applied_epoch_records = 0;
        self.shared.resyncs.fetch_add(1, Ordering::Relaxed);
        self.resync_ctr.inc();
        outcome.resynced = true;
        Ok(())
    }

    /// Open segment `seq` from the local cache, or ship it from the
    /// primary (atomically writing the local copy first, so a killed
    /// replica never caches a torn file).
    fn fetch_local_segment(&mut self, seq: u64, num_nodes: usize) -> Result<GraphStorage> {
        let path = segment_path(&self.dir, seq);
        if path.exists() {
            if let Ok(seg) = format::read_segment_backed(&path, self.backing) {
                if seg.num_nodes() == num_nodes {
                    self.reused_segments += 1;
                    return Ok(seg);
                }
            }
            // Unreadable or mismatched cache entry: re-ship below.
        }
        let bytes = self.log.fetch_segment(seq)?;
        self.shared.shipped_bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        self.shipped_ctr.add(bytes.len() as u64);
        format::write_atomic(&path, &bytes)?;
        let seg = format::read_segment_backed(&path, self.backing)?;
        if seg.num_nodes() != num_nodes {
            return Err(TgmError::Replica(format!(
                "segment {seq} spans {} nodes but the primary manifest says {num_nodes}",
                seg.num_nodes()
            )));
        }
        Ok(seg)
    }

    /// Pin the latest published generation. Typed error before the
    /// first publish (bootstrap publishes unless the primary is empty).
    pub fn pin(&self) -> Result<Arc<StorageSnapshot>> {
        self.cell.pin().ok_or_else(|| {
            TgmError::Serving(format!(
                "replica `{}` has not published a snapshot yet",
                self.name
            ))
        })
    }

    /// This replica's name (metrics label / serving identity).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The publication cell replicas of this store serve from (clones
    /// share one slot, like any [`SnapshotCell`]).
    pub fn cell(&self) -> SnapshotCell {
        self.cell.clone()
    }

    /// Counters shared with serving handles (see [`ReplicaShared`]).
    pub fn shared(&self) -> Arc<ReplicaShared> {
        Arc::clone(&self.shared)
    }

    /// Generation of the latest caught-up state.
    pub fn applied_generation(&self) -> u64 {
        self.shared.applied_generation()
    }

    /// Cumulative bytes fetched from the primary.
    pub fn shipped_bytes(&self) -> u64 {
        self.shared.shipped_bytes()
    }

    /// Sealed segments currently mirrored.
    pub fn num_sealed_segments(&self) -> usize {
        self.seqs.len()
    }

    /// Edge events applied (sealed + tail).
    pub fn total_edges(&self) -> usize {
        self.store.total_edges()
    }

    /// Move the replica onto a background thread polling at
    /// `interval`. Poll errors are counted
    /// (`tgm_replica_poll_errors_total`) and retried — transient races
    /// with primary seals and compactions are expected. Stop (and get
    /// the replica back) with [`ReplicaTailer::stop`]; dropping the
    /// tailer stops it too.
    pub fn spawn_tailer(self, interval: Duration) -> ReplicaTailer {
        let mut replica = self;
        let stop = Arc::new(AtomicBool::new(false));
        let shared = replica.shared();
        let cell = replica.cell();
        let flag = Arc::clone(&stop);
        let thread = std::thread::Builder::new()
            .name(format!("tgm-replica-{}", replica.name))
            .spawn(move || {
                while !flag.load(Ordering::Relaxed) {
                    if replica.poll().is_err() {
                        replica.poll_errors_ctr.inc();
                    }
                    std::thread::park_timeout(interval);
                }
                replica
            })
            .expect("failed to spawn replica tailer thread");
        ReplicaTailer { stop, shared, cell, thread: Some(thread) }
    }
}

/// Read the write-once static table from the local cache, or ship it.
/// Returns the features plus how many bytes were shipped.
fn fetch_static_cached(
    log: &dyn ReplicationLog,
    dir: &Path,
    m: &Manifest,
) -> Result<(Vec<f32>, u64)> {
    if m.static_feat_dim == 0 {
        return Ok((Vec::new(), 0));
    }
    let path = dir.join(STATIC_FILE);
    if path.exists() {
        if let Ok((dim, feats)) = format::read_static(&path) {
            if dim == m.static_feat_dim && feats.len() == dim * m.num_nodes {
                return Ok((feats, 0));
            }
        }
    }
    let bytes = log.fetch_static()?;
    let shipped = bytes.len() as u64;
    format::write_atomic(&path, &bytes)?;
    let (dim, feats) = format::decode_static(&bytes)?;
    if dim != m.static_feat_dim || feats.len() != dim * m.num_nodes {
        return Err(TgmError::Replica(format!(
            "static table holds {} values at dim {dim}, primary manifest expects {} x {}",
            feats.len(),
            m.num_nodes,
            m.static_feat_dim
        )));
    }
    Ok((feats, shipped))
}

/// Handle to a background tailer thread (see [`Replica::spawn_tailer`]).
pub struct ReplicaTailer {
    stop: Arc<AtomicBool>,
    shared: Arc<ReplicaShared>,
    cell: SnapshotCell,
    thread: Option<std::thread::JoinHandle<Replica>>,
}

impl ReplicaTailer {
    /// The replica's publication cell (for serving handles).
    pub fn cell(&self) -> SnapshotCell {
        self.cell.clone()
    }

    /// The replica's shared counters.
    pub fn shared(&self) -> Arc<ReplicaShared> {
        Arc::clone(&self.shared)
    }

    /// Stop the tailer and get the [`Replica`] back (e.g. to poll it
    /// manually or drop it cleanly).
    pub fn stop(mut self) -> Replica {
        self.stop.store(true, Ordering::Relaxed);
        let thread = self.thread.take().expect("replica tailer already joined");
        thread.thread().unpark();
        thread.join().expect("replica tailer thread panicked")
    }
}

impl Drop for ReplicaTailer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(thread) = self.thread.take() {
            thread.thread().unpark();
            let _ = thread.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeEvent, SealPolicy};
    use crate::persist::DurabilityPolicy;

    fn test_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "tgm_replica_test_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn edge(t: i64, src: u32, dst: u32) -> EdgeEvent {
        EdgeEvent { t, src, dst, features: vec![t as f32, 0.25] }
    }

    fn primary(dir: &Path, seal_every: usize) -> SegmentedStorage {
        SegmentedStorage::new(16, SealPolicy::by_events(seal_every))
            .with_durability(DurabilityPolicy::new(dir))
            .unwrap()
    }

    fn assert_same_content(primary: &mut SegmentedStorage, replica: &mut Replica) {
        let a = primary.snapshot().unwrap();
        let b = replica.pin().unwrap();
        assert_eq!(a.generation(), b.generation(), "generations diverge");
        assert_eq!(a.num_edges(), b.num_edges());
        assert_eq!(a.granularity(), b.granularity(), "inferred granularity diverges");
        for i in 0..a.num_edges() {
            assert_eq!(a.edge_ts(i), b.edge_ts(i), "edge {i} ts");
            assert_eq!(a.edge_src(i), b.edge_src(i), "edge {i} src");
            assert_eq!(a.edge_dst(i), b.edge_dst(i), "edge {i} dst");
        }
    }

    #[test]
    fn replica_bootstraps_from_a_live_primary_and_tails_appends() {
        let pdir = test_dir("tail_primary");
        let rdir = test_dir("tail_replica");
        let mut p = primary(&pdir, 4);
        for i in 0..10 {
            p.append_edge(edge(1_000 * (i + 1), 0, 1)).unwrap();
        }
        // 2 sealed segments + 2 events in the WAL tail; the primary
        // stays live (lock held) the whole time.
        let log = Arc::new(DirTransport::new(&pdir));
        let (mut r, report) =
            Replica::bootstrap("r0", log, ReplicaConfig::new(&rdir)).unwrap();
        assert_eq!(report.segments, 2);
        assert_eq!(report.replayed_events, 2);
        assert!(report.shipped_bytes > 0);
        assert_eq!(report.generation, p.generation());
        assert_same_content(&mut p, &mut r);

        // New appends on the primary stream over through the tail...
        p.append_edge(edge(11_000, 2, 3)).unwrap();
        let o = r.poll().unwrap();
        assert!(o.published);
        assert_eq!(o.applied_events, 1);
        assert_same_content(&mut p, &mut r);

        // ...and a seal replaces the replayed tail with the sealed
        // file, without double-applying across the epoch fence.
        p.append_edge(edge(12_000, 2, 3)).unwrap();
        assert!(p.append_edge(edge(13_000, 2, 4)).unwrap(), "this append should seal");
        let o = r.poll().unwrap();
        assert_eq!(o.installed_segments, 1);
        assert_same_content(&mut p, &mut r);
    }

    #[test]
    fn compaction_ships_one_delta_and_never_rebootstraps() {
        let pdir = test_dir("delta_primary");
        let rdir = test_dir("delta_replica");
        let mut p = primary(&pdir, 4);
        for i in 0..16 {
            p.append_edge(edge(500 * (i + 1), 1, 2)).unwrap();
        }
        let log: Arc<dyn ReplicationLog> = Arc::new(DirTransport::new(&pdir));
        let (mut r, report) =
            Replica::bootstrap("r1", Arc::clone(&log), ReplicaConfig::new(&rdir)).unwrap();
        assert_eq!(report.segments, 4);
        let shipped_before = r.shipped_bytes();

        assert!(p.compact().unwrap());
        let o = r.poll().unwrap();
        assert_eq!(o.installed_segments, 1, "one merged file replaces the whole run");
        assert!(!o.resynced);
        assert_eq!(r.num_sealed_segments(), 1);
        let delta = r.shipped_bytes() - shipped_before;
        assert!(delta > 0, "the merged segment itself must ship");
        assert_same_content(&mut p, &mut r);

        // A replica restart re-fetches nothing: every live file is
        // already cached locally under its primary seq.
        drop(r);
        let (mut r2, report2) =
            Replica::bootstrap("r1b", log, ReplicaConfig::new(&rdir)).unwrap();
        assert_eq!(report2.reused_segments, 1);
        assert_eq!(report2.shipped_bytes, 0, "bootstrap bytes are never re-shipped");
        assert_same_content(&mut p, &mut r2);
    }

    #[test]
    fn tailer_thread_keeps_a_replica_within_bounded_lag() {
        let pdir = test_dir("tailer_primary");
        let rdir = test_dir("tailer_replica");
        let mut p = primary(&pdir, 32);
        p.append_edge(edge(10, 0, 1)).unwrap();
        let (r, _) = Replica::bootstrap(
            "r2",
            Arc::new(DirTransport::new(&pdir)),
            ReplicaConfig::new(&rdir),
        )
        .unwrap();
        let tailer = r.spawn_tailer(Duration::from_millis(1));
        for i in 0..200 {
            p.append_edge(edge(20 + i, 0, 1)).unwrap();
        }
        let target = p.generation();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while tailer.shared().applied_generation() < target {
            assert!(std::time::Instant::now() < deadline, "replica never caught up");
            std::thread::sleep(Duration::from_millis(2));
        }
        let mut r = tailer.stop();
        assert_same_content(&mut p, &mut r);
        assert!(r.shared().lag_us().is_some());
    }
}
