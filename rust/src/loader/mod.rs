//! Data loading: unified CTDG/DTDG iteration (paper Definitions 3.3/3.4,
//! Fig. 2).
//!
//! Iteration is split into two steps shared by both loaders:
//!
//! 1. [`plan_batches`] turns a [`DGraph`] view plus a [`BatchBy`] strategy
//!    into an explicit list of [`BatchPlan`]s — the batch boundaries
//!    (event ranges and time windows) are fully determined *before* any
//!    batch is materialized. Planning is what makes parallel prefetch
//!    deterministic: every worker sees the same plan, and per-batch RNG
//!    seeds derive from the plan index.
//! 2. [`materialize_window`] turns one plan entry into a seed
//!    [`MaterializedBatch`] (columns + base attributes), after which the
//!    hook phases run.
//!
//! [`DGDataLoader`] executes the plan serially on the calling thread;
//! [`PrefetchLoader`] materializes plans on a worker pool and applies the
//! stateful hook phase in order on receive, yielding byte-identical
//! batches (see `prefetch` module docs). The pool itself is a standalone
//! [`ServingPool`]: many concurrent iterations ([`PooledStream`]s — one
//! per tenant graph under [`crate::serving::TenantRouter`]) multiplex
//! over one fixed set of workers, while `PrefetchLoader` remains the
//! exclusive single-stream façade over a dedicated pool.
//!
//! Strategies:
//!
//! * **By events** (CTDG): fixed-size batches of consecutive events,
//!   independent of wall-clock time — the view's granularity is the
//!   special event-ordered τ_event.
//! * **By time** (DTDG): each batch spans exactly one bucket of a coarser
//!   wall-clock granularity τ̂, so batch *duration* is fixed while edge
//!   counts vary — snapshot iteration.

pub mod affinity;
pub mod pool;
pub mod prefetch;
pub mod sched;

pub use pool::{PointTicket, PooledStream, QosStats, QueueDepth, ServingPool, StreamConfig};
pub use prefetch::{PrefetchConfig, PrefetchLoader, PrefetchStats};
pub use sched::{LatencyHistogram, QosTag, RequestClass, Scheduler, SchedulerKind};

use crate::error::{Result, TgmError};
use crate::graph::{DGraph, StorageSnapshot};
use crate::hooks::batch::{attr, MaterializedBatch};
use crate::hooks::manager::HookManager;
use crate::util::{Tensor, TimeGranularity, Timestamp};

/// Iteration strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchBy {
    /// CTDG: fixed number of events per batch.
    Events(usize),
    /// DTDG: one batch per granularity bucket (the view's granularity
    /// must be a wall-clock unit coarser than native).
    Time(TimeGranularity),
}

/// One planned batch: the storage event range `[lo, hi)` and the time
/// window `[t0, t1)` it covers, plus its position in the iteration.
#[derive(Debug, Clone)]
pub struct BatchPlan {
    /// Ordinal within the plan (drives per-batch RNG seeds).
    pub index: usize,
    /// First storage event index (inclusive).
    pub lo: usize,
    /// Last storage event index (exclusive).
    pub hi: usize,
    /// Inclusive window start.
    pub t0: Timestamp,
    /// Exclusive window end.
    pub t1: Timestamp,
}

impl BatchPlan {
    /// Number of seed events in this batch.
    pub fn num_edges(&self) -> usize {
        self.hi - self.lo
    }
}

/// Validate a strategy against a view (strategy errors surface at loader
/// construction, before any planning).
fn validate_strategy(view: &DGraph, by: BatchBy) -> Result<()> {
    match by {
        BatchBy::Events(b) => {
            if b == 0 {
                return Err(TgmError::Config("batch size must be positive".into()));
            }
            Ok(())
        }
        BatchBy::Time(g) => {
            if !g.is_coarser_or_equal(&view.storage().granularity()) {
                return Err(TgmError::Time(format!(
                    "iteration granularity {} finer than native {}",
                    g.as_str(),
                    view.storage().granularity().as_str()
                )));
            }
            Ok(())
        }
    }
}

/// Bucket index range `[first, last)` the view spans at granularity `g`.
/// A view containing a single timestamp `t` spans exactly one bucket
/// (the `end_time() - 1` term keeps the exclusive bound from spilling
/// into the next bucket).
fn time_bucket_range(view: &DGraph, g: TimeGranularity) -> Result<(i64, i64)> {
    let first = g.bucket_of(view.start_time(), 0)?;
    let last = if view.end_time() > view.start_time() {
        g.bucket_of(view.end_time() - 1, 0)? + 1
    } else {
        first
    };
    Ok((first, last))
}

/// Plan all batch boundaries for a view up front.
///
/// * `skip_empty` drops time buckets with zero edge events (DTDG
///   snapshots may be empty); with it unset, one empty batch per empty
///   bucket is planned.
/// * `event_cap` splits oversized time buckets into consecutive chunks of
///   at most `cap` events sharing the bucket's window (used to respect
///   AOT batch envelopes). Event iteration is already fixed-size.
pub fn plan_batches(
    view: &DGraph,
    by: BatchBy,
    skip_empty: bool,
    event_cap: usize,
) -> Result<Vec<BatchPlan>> {
    validate_strategy(view, by)?;
    let cap = event_cap.max(1);
    let storage = view.storage();
    let mut plans: Vec<BatchPlan> = Vec::new();
    match by {
        BatchBy::Events(bsz) => {
            let idx = view.edge_indices();
            let mut lo = idx.start;
            while lo < idx.end {
                let hi = (lo + bsz).min(idx.end);
                plans.push(BatchPlan {
                    index: plans.len(),
                    lo,
                    hi,
                    t0: storage.edge_ts_at(lo),
                    t1: storage.edge_ts_at(hi - 1) + 1,
                });
                lo = hi;
            }
        }
        BatchBy::Time(g) => {
            let (first, last) = time_bucket_range(view, g)?;
            for bkt in first..last {
                let t0 = g.bucket_start(bkt, 0)?.max(view.start_time());
                let t1 = g.bucket_start(bkt + 1, 0)?.min(view.end_time());
                let r = storage.edge_range(t0, t1);
                if r.is_empty() {
                    if !skip_empty {
                        plans.push(BatchPlan { index: plans.len(), lo: r.start, hi: r.start, t0, t1 });
                    }
                    continue;
                }
                let mut lo = r.start;
                while lo < r.end {
                    let hi = lo.saturating_add(cap).min(r.end);
                    plans.push(BatchPlan { index: plans.len(), lo, hi, t0, t1 });
                    lo = hi;
                }
            }
        }
    }
    Ok(plans)
}

/// Materialize the seed columns and base attributes (`A₀`) for one
/// planned batch. Pure function of (snapshot, plan) — safe on any thread.
/// The logical event range is copied segment-chunk by segment-chunk, so
/// the cost is identical for single- and multi-segment snapshots up to
/// one extra `memcpy` split per segment boundary inside the window.
pub fn materialize_window(storage: &StorageSnapshot, plan: &BatchPlan) -> Result<MaterializedBatch> {
    let (lo, hi) = (plan.lo, plan.hi);
    let mut b = MaterializedBatch::new(plan.t0, plan.t1);
    let n = hi - lo;
    let d = storage.edge_feat_dim();
    b.src.reserve(n);
    b.dst.reserve(n);
    b.ts.reserve(n);
    b.edge_indices.reserve(n);
    let mut feats = Vec::with_capacity(n * d);
    for (seg, local) in storage.edge_chunks(lo..hi) {
        b.src.extend_from_slice(&seg.edge_src()[local.clone()]);
        b.dst.extend_from_slice(&seg.edge_dst()[local.clone()]);
        b.ts.extend_from_slice(&seg.edge_ts()[local.clone()]);
        feats.extend_from_slice(&seg.edge_feats()[local.start * d..local.end * d]);
    }
    b.edge_indices.extend((lo as u32)..(hi as u32));
    let ner = storage.node_event_range(plan.t0, plan.t1);
    for (seg, local) in storage.node_event_chunks(ner) {
        for i in local {
            b.node_events.push((seg.node_event_ts()[i], seg.node_event_ids()[i]));
        }
    }

    // Base attributes (the A₀ recipes validate against).
    b.set(attr::SRC, Tensor::i32(b.src.iter().map(|&x| x as i32).collect(), &[n])?);
    b.set(attr::DST, Tensor::i32(b.dst.iter().map(|&x| x as i32).collect(), &[n])?);
    b.set(attr::TIME, Tensor::f32(b.ts.iter().map(|&t| t as f32).collect(), &[n])?);
    b.set(attr::EDGE_FEATS, Tensor::f32(feats, &[n, d])?);
    Ok(b)
}

/// Serial loader over one view. Yields materialized batches with both
/// hook phases applied on the calling thread.
pub struct DGDataLoader<'a> {
    view: DGraph,
    by: BatchBy,
    manager: &'a mut HookManager,
    /// Skip batches with zero edge events (DTDG snapshots may be empty).
    skip_empty: bool,
    /// Max edge events per yielded batch for time iteration.
    event_cap: usize,
    /// Added to every plan index when running hooks: lets a caller that
    /// iterates one logical epoch through several loaders (e.g. the
    /// streaming trainer's per-cycle windows) keep per-batch RNG seeds
    /// globally unique instead of restarting at 0 each window.
    index_offset: usize,
    plans: Option<Vec<BatchPlan>>,
    pos: usize,
}

impl<'a> DGDataLoader<'a> {
    /// Create a loader; validates the strategy against the view.
    pub fn new(view: DGraph, by: BatchBy, manager: &'a mut HookManager) -> Result<DGDataLoader<'a>> {
        validate_strategy(&view, by)?;
        Ok(DGDataLoader {
            view,
            by,
            manager,
            skip_empty: true,
            event_cap: usize::MAX,
            index_offset: 0,
            plans: None,
            pos: 0,
        })
    }

    /// Include empty snapshots (only meaningful for time iteration).
    pub fn with_empty_batches(mut self) -> Self {
        self.skip_empty = false;
        self.plans = None;
        self
    }

    /// Split oversized time-iteration buckets into chunks of at most
    /// `cap` events (same window on every chunk).
    pub fn with_event_cap(mut self, cap: usize) -> Self {
        self.event_cap = cap.max(1);
        self.plans = None;
        self
    }

    /// Offset added to every plan index when hooks run (continuing one
    /// logical epoch across several windowed loaders).
    pub fn with_index_offset(mut self, offset: usize) -> Self {
        self.index_offset = offset;
        self
    }

    /// The wrapped view.
    pub fn view(&self) -> &DGraph {
        &self.view
    }

    /// Number of batches this loader will yield. Exact once the plan is
    /// forced (after the first `next()`); before that it is an estimate
    /// for time iteration — the bucket count, which over-counts when
    /// `skip_empty` drops empty buckets and under-counts when
    /// `with_event_cap` splits oversized ones.
    pub fn num_batches_hint(&self) -> usize {
        if let Some(plans) = &self.plans {
            return plans.len() - self.pos;
        }
        match self.by {
            BatchBy::Events(b) => self.view.num_edges().div_ceil(b),
            BatchBy::Time(g) => time_bucket_range(&self.view, g)
                .map(|(first, last)| (last - first).max(0) as usize)
                .unwrap_or(0),
        }
    }

    fn ensure_plans(&mut self) -> Result<()> {
        if self.plans.is_none() {
            self.plans = Some(plan_batches(&self.view, self.by, self.skip_empty, self.event_cap)?);
        }
        Ok(())
    }

    /// Next batch, or `None` when exhausted.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Option<Result<MaterializedBatch>> {
        if let Err(e) = self.ensure_plans() {
            // Poison the plan so subsequent calls terminate the stream.
            self.plans = Some(Vec::new());
            return Some(Err(e));
        }
        let plan = {
            // `ensure_plans` just populated this; an empty fallback (not
            // a panic) simply ends the iteration.
            let plans = self.plans.as_deref().unwrap_or_default();
            if self.pos >= plans.len() {
                return None;
            }
            plans[self.pos].clone()
        };
        self.pos += 1;
        let storage = std::sync::Arc::clone(self.view.storage());
        let mut batch = match materialize_window(&storage, &plan) {
            Ok(b) => b,
            Err(e) => return Some(Err(e)),
        };
        if let Err(e) =
            self.manager.run_indexed(&mut batch, &storage, self.index_offset + plan.index)
        {
            return Some(Err(e));
        }
        Some(Ok(batch))
    }

    /// Drain all remaining batches (convenience for tests/benches).
    pub fn collect_all(&mut self) -> Result<Vec<MaterializedBatch>> {
        let mut out = Vec::new();
        while let Some(b) = self.next() {
            out.push(b?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DGData, EdgeEvent, GraphStorage, Task};
    use crate::hooks::recipes::{RecipeRegistry, RECIPE_SNAPSHOT, RECIPE_TGB_LINK};

    fn data() -> DGData {
        // 120 events, one per minute => spans 2 hours.
        let edges = (0..120)
            .map(|i| EdgeEvent {
                t: i as i64 * 60,
                src: (i % 3) as u32,
                dst: 3 + (i % 2) as u32,
                features: vec![i as f32],
            })
            .collect();
        let st = GraphStorage::from_events(edges, vec![], 5, None, None).unwrap();
        DGData::new(st, "toy", Task::LinkPrediction)
    }

    #[test]
    fn event_iteration_fixed_batches() {
        let d = data();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("train").unwrap();
        let mut loader = DGDataLoader::new(d.full(), BatchBy::Events(50), &mut m).unwrap();
        assert_eq!(loader.num_batches_hint(), 3);
        let batches = loader.collect_all().unwrap();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].num_edges(), 50);
        assert_eq!(batches[1].num_edges(), 50);
        assert_eq!(batches[2].num_edges(), 20);
        // Hook outputs present on every batch.
        assert!(batches.iter().all(|b| b.has(attr::NEIGHBORS)));
        // Chronological, non-overlapping coverage.
        assert!(batches[0].ts.last().unwrap() < batches[1].ts.first().unwrap());
    }

    #[test]
    fn time_iteration_fixed_duration() {
        let d = data();
        let mut m = RecipeRegistry::build(RECIPE_SNAPSHOT).unwrap();
        m.activate("train").unwrap();
        let mut loader =
            DGDataLoader::new(d.full(), BatchBy::Time(TimeGranularity::Hour), &mut m).unwrap();
        let batches = loader.collect_all().unwrap();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].num_edges(), 60);
        assert_eq!(batches[1].num_edges(), 60);
        // Every batch spans exactly one hour bucket.
        assert!(batches[0].end - batches[0].start <= 3600);
        assert!(batches.iter().all(|b| b.has(attr::SNAPSHOT_ADJ)));
    }

    #[test]
    fn time_iteration_skips_or_keeps_empty_buckets() {
        // Events only in hours 0 and 3.
        let edges = vec![
            EdgeEvent { t: 0, src: 0, dst: 1, features: vec![] },
            EdgeEvent { t: 3 * 3600 + 5, src: 1, dst: 0, features: vec![] },
        ];
        let st = GraphStorage::from_events(edges, vec![], 2, None, None).unwrap();
        let d = DGData::new(st, "sparse", Task::LinkPrediction);

        let mut m = RecipeRegistry::build(RECIPE_SNAPSHOT).unwrap();
        m.activate("train").unwrap();
        let mut l1 =
            DGDataLoader::new(d.full(), BatchBy::Time(TimeGranularity::Hour), &mut m).unwrap();
        assert_eq!(l1.collect_all().unwrap().len(), 2);

        let mut l2 = DGDataLoader::new(d.full(), BatchBy::Time(TimeGranularity::Hour), &mut m)
            .unwrap()
            .with_empty_batches();
        let all = l2.collect_all().unwrap();
        assert_eq!(all.len(), 4);
        assert_eq!(all[1].num_edges(), 0);
        // Empty batches still carry (empty) base attributes.
        assert_eq!(all[1].get(attr::SRC).unwrap().shape(), &[0]);
    }

    #[test]
    fn event_cap_splits_oversized_buckets() {
        // Two hour-buckets of 60 events each; cap 25 => 25+25+10 per
        // bucket => 6 batches total, chunks share their bucket's window.
        let d = data();
        let mut m = RecipeRegistry::build(RECIPE_SNAPSHOT).unwrap();
        m.activate("train").unwrap();
        let mut loader = DGDataLoader::new(d.full(), BatchBy::Time(TimeGranularity::Hour), &mut m)
            .unwrap()
            .with_event_cap(25);
        let batches = loader.collect_all().unwrap();
        assert_eq!(batches.len(), 6);
        assert_eq!(
            batches.iter().map(|b| b.num_edges()).collect::<Vec<_>>(),
            vec![25, 25, 10, 25, 25, 10]
        );
        assert!(batches.iter().all(|b| b.num_edges() <= 25));
        // Chunks of one bucket share the window; totals are preserved.
        assert_eq!(batches[0].start, batches[2].start);
        assert_eq!(batches[0].end, batches[2].end);
        assert_ne!(batches[2].start, batches[3].start);
        assert_eq!(batches.iter().map(|b| b.num_edges()).sum::<usize>(), 120);
    }

    #[test]
    fn single_timestamp_view_iterates_once() {
        // All events share one timestamp: the `end_time() - 1` bucket
        // math must span exactly one bucket, not zero and not two.
        let edges = (0..10)
            .map(|i| EdgeEvent {
                t: 5000,
                src: (i % 2) as u32,
                dst: ((i + 1) % 2) as u32,
                features: vec![],
            })
            .collect();
        let st =
            GraphStorage::from_events(edges, vec![], 2, None, Some(TimeGranularity::Second))
                .unwrap();
        let d = DGData::new(st, "point", Task::LinkPrediction);
        let mut m = RecipeRegistry::build(RECIPE_SNAPSHOT).unwrap();
        m.activate("train").unwrap();
        let mut loader =
            DGDataLoader::new(d.full(), BatchBy::Time(TimeGranularity::Hour), &mut m).unwrap();
        let batches = loader.collect_all().unwrap();
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].num_edges(), 10);
        // The window is clamped to the view, inside hour bucket 1.
        assert_eq!(batches[0].start, 5000);
        assert_eq!(batches[0].end, 5001);
    }

    #[test]
    fn empty_window_view_yields_no_batches() {
        let d = data();
        let view = d.full().slice(600, 600).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_SNAPSHOT).unwrap();
        m.activate("train").unwrap();
        let mut by_time =
            DGDataLoader::new(view.clone(), BatchBy::Time(TimeGranularity::Hour), &mut m).unwrap();
        assert!(by_time.next().is_none());
        let mut by_events = DGDataLoader::new(view, BatchBy::Events(10), &mut m).unwrap();
        assert!(by_events.next().is_none());
    }

    #[test]
    fn planner_indices_are_dense_and_ordered() {
        let d = data();
        let plans =
            plan_batches(&d.full(), BatchBy::Time(TimeGranularity::Hour), true, 25).unwrap();
        for (i, p) in plans.iter().enumerate() {
            assert_eq!(p.index, i);
            assert!(p.lo <= p.hi);
            assert!(p.t0 < p.t1);
        }
        // Consecutive chunks tile the event range.
        for w in plans.windows(2) {
            assert_eq!(w[0].hi, w[1].lo);
        }
    }

    #[test]
    fn finer_than_native_rejected() {
        // Native granularity is Minute; Second iteration must fail.
        let d = data();
        let mut m = RecipeRegistry::build(RECIPE_SNAPSHOT).unwrap();
        assert!(
            DGDataLoader::new(d.full(), BatchBy::Time(TimeGranularity::Second), &mut m).is_err()
        );
        assert!(DGDataLoader::new(d.full(), BatchBy::Events(0), &mut m).is_err());
    }

    #[test]
    fn base_attrs_are_materialized() {
        let d = data();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("train").unwrap();
        let mut loader = DGDataLoader::new(d.full(), BatchBy::Events(40), &mut m).unwrap();
        let b = loader.next().unwrap().unwrap();
        assert_eq!(b.get(attr::SRC).unwrap().shape(), &[40]);
        assert_eq!(b.get(attr::TIME).unwrap().shape(), &[40]);
        assert_eq!(b.get(attr::EDGE_FEATS).unwrap().shape(), &[40, 1]);
        // Feature column matches storage rows.
        assert_eq!(b.get(attr::EDGE_FEATS).unwrap().as_f32().unwrap()[0], 0.0);
    }

    #[test]
    fn split_views_iterate_consistently() {
        let d = data();
        let splits = d.split().unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("train").unwrap();
        let total: usize = [&splits.train, &splits.val, &splits.test]
            .iter()
            .map(|v| {
                let mut l = DGDataLoader::new((*v).clone(), BatchBy::Events(32), &mut m).unwrap();
                l.collect_all().unwrap().iter().map(|b| b.num_edges()).sum::<usize>()
            })
            .sum();
        assert_eq!(total, 120);
    }
}
