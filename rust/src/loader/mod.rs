//! Data loading: unified CTDG/DTDG iteration (paper Definitions 3.3/3.4,
//! Fig. 2).
//!
//! [`DGDataLoader`] turns a [`DGraph`] view into a stream of
//! [`MaterializedBatch`]es:
//!
//! * **By events** (CTDG): fixed-size batches of consecutive events,
//!   independent of wall-clock time — the view's granularity is the
//!   special event-ordered τ_event.
//! * **By time** (DTDG): each batch spans exactly one bucket of a coarser
//!   wall-clock granularity τ̂, so batch *duration* is fixed while edge
//!   counts vary — snapshot iteration.
//!
//! The loader materializes seed columns, then runs the injected
//! [`HookManager`]'s active recipe over each batch, so models receive all
//! declared attributes transparently (paper Fig. 5).

use crate::error::{Result, TgmError};
use crate::graph::DGraph;
use crate::hooks::batch::{attr, MaterializedBatch};
use crate::hooks::manager::HookManager;
use crate::util::{Tensor, TimeGranularity, Timestamp};

/// Iteration strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchBy {
    /// CTDG: fixed number of events per batch.
    Events(usize),
    /// DTDG: one batch per granularity bucket (the view's granularity
    /// must be a wall-clock unit coarser than native).
    Time(TimeGranularity),
}

/// Loader over one view. Yields materialized batches with hooks applied.
pub struct DGDataLoader<'a> {
    view: DGraph,
    by: BatchBy,
    manager: &'a mut HookManager,
    /// Skip batches with zero edge events (DTDG snapshots may be empty).
    skip_empty: bool,
    /// Max edge events per yielded batch for time iteration; oversized
    /// buckets are split into consecutive chunks sharing the window
    /// (used to respect AOT batch envelopes).
    event_cap: usize,
    cursor_event: usize,
    cursor_bucket: i64,
    end_bucket: i64,
    /// Partially consumed bucket: (remaining range, window).
    pending_bucket: Option<(std::ops::Range<usize>, Timestamp, Timestamp)>,
}

impl<'a> DGDataLoader<'a> {
    /// Create a loader; validates the strategy against the view.
    pub fn new(view: DGraph, by: BatchBy, manager: &'a mut HookManager) -> Result<DGDataLoader<'a>> {
        let (cursor_bucket, end_bucket) = match by {
            BatchBy::Events(b) => {
                if b == 0 {
                    return Err(TgmError::Config("batch size must be positive".into()));
                }
                (0, 0)
            }
            BatchBy::Time(g) => {
                if !g.is_coarser_or_equal(&view.storage().granularity()) {
                    return Err(TgmError::Time(format!(
                        "iteration granularity {} finer than native {}",
                        g.as_str(),
                        view.storage().granularity().as_str()
                    )));
                }
                let first = g.bucket_of(view.start_time(), 0)?;
                let last = if view.end_time() > view.start_time() {
                    g.bucket_of(view.end_time() - 1, 0)? + 1
                } else {
                    first
                };
                (first, last)
            }
        };
        Ok(DGDataLoader {
            view,
            by,
            manager,
            skip_empty: true,
            event_cap: usize::MAX,
            cursor_event: 0,
            cursor_bucket,
            end_bucket,
            pending_bucket: None,
        })
    }

    /// Include empty snapshots (only meaningful for time iteration).
    pub fn with_empty_batches(mut self) -> Self {
        self.skip_empty = false;
        self
    }

    /// Split oversized time-iteration buckets into chunks of at most
    /// `cap` events (same window on every chunk).
    pub fn with_event_cap(mut self, cap: usize) -> Self {
        self.event_cap = cap.max(1);
        self
    }

    /// The wrapped view.
    pub fn view(&self) -> &DGraph {
        &self.view
    }

    /// Number of batches this loader will yield (upper bound when
    /// `skip_empty` is set).
    pub fn num_batches_hint(&self) -> usize {
        match self.by {
            BatchBy::Events(b) => self.view.num_edges().div_ceil(b),
            BatchBy::Time(_) => (self.end_bucket - self.cursor_bucket).max(0) as usize,
        }
    }

    /// Materialize seed columns for a window and run hooks.
    fn materialize(&mut self, t0: Timestamp, t1: Timestamp, lo: usize, hi: usize) -> Result<MaterializedBatch> {
        let storage = self.view.storage();
        let mut b = MaterializedBatch::new(t0, t1);
        let n = hi - lo;
        b.src.reserve(n);
        b.dst.reserve(n);
        b.ts.reserve(n);
        b.edge_indices.reserve(n);
        b.src.extend_from_slice(&storage.edge_src()[lo..hi]);
        b.dst.extend_from_slice(&storage.edge_dst()[lo..hi]);
        b.ts.extend_from_slice(&storage.edge_ts()[lo..hi]);
        b.edge_indices.extend((lo as u32)..(hi as u32));
        let ner = storage.node_event_range(t0, t1);
        for i in ner {
            b.node_events.push((storage.node_event_ts()[i], storage.node_event_ids()[i]));
        }

        // Base attributes (the A₀ recipes validate against).
        b.set(attr::SRC, Tensor::i32(b.src.iter().map(|&x| x as i32).collect(), &[n])?);
        b.set(attr::DST, Tensor::i32(b.dst.iter().map(|&x| x as i32).collect(), &[n])?);
        b.set(attr::TIME, Tensor::f32(b.ts.iter().map(|&t| t as f32).collect(), &[n])?);
        let d = storage.edge_feat_dim();
        let feats = storage.edge_feats()[lo * d..hi * d].to_vec();
        b.set(attr::EDGE_FEATS, Tensor::f32(feats, &[n, d])?);

        let storage = std::sync::Arc::clone(storage);
        self.manager.run(&mut b, &storage)?;
        Ok(b)
    }

    /// Next batch, or `None` when exhausted.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Option<Result<MaterializedBatch>> {
        match self.by {
            BatchBy::Events(bsz) => {
                let idx = self.view.edge_indices();
                let lo = idx.start + self.cursor_event;
                if lo >= idx.end {
                    return None;
                }
                let hi = (lo + bsz).min(idx.end);
                self.cursor_event += hi - lo;
                let storage = self.view.storage();
                let t0 = storage.edge_ts()[lo];
                let t1 = storage.edge_ts()[hi - 1] + 1;
                Some(self.materialize(t0, t1, lo, hi))
            }
            BatchBy::Time(g) => {
                if let Some((rest, t0, t1)) = self.pending_bucket.take() {
                    let hi = rest.start.saturating_add(self.event_cap).min(rest.end);
                    if hi < rest.end {
                        self.pending_bucket = Some((hi..rest.end, t0, t1));
                    }
                    return Some(self.materialize(t0, t1, rest.start, hi));
                }
                while self.cursor_bucket < self.end_bucket {
                    let bkt = self.cursor_bucket;
                    self.cursor_bucket += 1;
                    let t0 = match g.bucket_start(bkt, 0) {
                        Ok(t) => t.max(self.view.start_time()),
                        Err(e) => return Some(Err(e)),
                    };
                    let t1 = match g.bucket_start(bkt + 1, 0) {
                        Ok(t) => t.min(self.view.end_time()),
                        Err(e) => return Some(Err(e)),
                    };
                    let r = self.view.storage().edge_range(t0, t1);
                    if r.is_empty() && self.skip_empty {
                        continue;
                    }
                    let hi = r.start.saturating_add(self.event_cap).min(r.end);
                    if hi < r.end {
                        self.pending_bucket = Some((hi..r.end, t0, t1));
                    }
                    return Some(self.materialize(t0, t1, r.start, hi));
                }
                None
            }
        }
    }

    /// Drain all remaining batches (convenience for tests/benches).
    pub fn collect_all(&mut self) -> Result<Vec<MaterializedBatch>> {
        let mut out = Vec::new();
        while let Some(b) = self.next() {
            out.push(b?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DGData, EdgeEvent, GraphStorage, Task};
    use crate::hooks::recipes::{RecipeRegistry, RECIPE_SNAPSHOT, RECIPE_TGB_LINK};

    fn data() -> DGData {
        // 120 events, one per minute => spans 2 hours.
        let edges = (0..120)
            .map(|i| EdgeEvent {
                t: i as i64 * 60,
                src: (i % 3) as u32,
                dst: 3 + (i % 2) as u32,
                features: vec![i as f32],
            })
            .collect();
        let st = GraphStorage::from_events(edges, vec![], 5, None, None).unwrap();
        DGData::new(st, "toy", Task::LinkPrediction)
    }

    #[test]
    fn event_iteration_fixed_batches() {
        let d = data();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("train").unwrap();
        let mut loader = DGDataLoader::new(d.full(), BatchBy::Events(50), &mut m).unwrap();
        assert_eq!(loader.num_batches_hint(), 3);
        let batches = loader.collect_all().unwrap();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].num_edges(), 50);
        assert_eq!(batches[1].num_edges(), 50);
        assert_eq!(batches[2].num_edges(), 20);
        // Hook outputs present on every batch.
        assert!(batches.iter().all(|b| b.has(attr::NEIGHBORS)));
        // Chronological, non-overlapping coverage.
        assert!(batches[0].ts.last().unwrap() < batches[1].ts.first().unwrap());
    }

    #[test]
    fn time_iteration_fixed_duration() {
        let d = data();
        let mut m = RecipeRegistry::build(RECIPE_SNAPSHOT).unwrap();
        m.activate("train").unwrap();
        let mut loader =
            DGDataLoader::new(d.full(), BatchBy::Time(TimeGranularity::Hour), &mut m).unwrap();
        let batches = loader.collect_all().unwrap();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].num_edges(), 60);
        assert_eq!(batches[1].num_edges(), 60);
        // Every batch spans exactly one hour bucket.
        assert!(batches[0].end - batches[0].start <= 3600);
        assert!(batches.iter().all(|b| b.has(attr::SNAPSHOT_ADJ)));
    }

    #[test]
    fn time_iteration_skips_or_keeps_empty_buckets() {
        // Events only in hours 0 and 3.
        let edges = vec![
            EdgeEvent { t: 0, src: 0, dst: 1, features: vec![] },
            EdgeEvent { t: 3 * 3600 + 5, src: 1, dst: 0, features: vec![] },
        ];
        let st = GraphStorage::from_events(edges, vec![], 2, None, None).unwrap();
        let d = DGData::new(st, "sparse", Task::LinkPrediction);

        let mut m = RecipeRegistry::build(RECIPE_SNAPSHOT).unwrap();
        m.activate("train").unwrap();
        let mut l1 =
            DGDataLoader::new(d.full(), BatchBy::Time(TimeGranularity::Hour), &mut m).unwrap();
        assert_eq!(l1.collect_all().unwrap().len(), 2);

        let mut l2 = DGDataLoader::new(d.full(), BatchBy::Time(TimeGranularity::Hour), &mut m)
            .unwrap()
            .with_empty_batches();
        let all = l2.collect_all().unwrap();
        assert_eq!(all.len(), 4);
        assert_eq!(all[1].num_edges(), 0);
    }

    #[test]
    fn finer_than_native_rejected() {
        // Native granularity is Minute; Second iteration must fail.
        let d = data();
        let mut m = RecipeRegistry::build(RECIPE_SNAPSHOT).unwrap();
        assert!(
            DGDataLoader::new(d.full(), BatchBy::Time(TimeGranularity::Second), &mut m).is_err()
        );
        assert!(DGDataLoader::new(d.full(), BatchBy::Events(0), &mut m).is_err());
    }

    #[test]
    fn base_attrs_are_materialized() {
        let d = data();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("train").unwrap();
        let mut loader = DGDataLoader::new(d.full(), BatchBy::Events(40), &mut m).unwrap();
        let b = loader.next().unwrap().unwrap();
        assert_eq!(b.get(attr::SRC).unwrap().shape(), &[40]);
        assert_eq!(b.get(attr::TIME).unwrap().shape(), &[40]);
        assert_eq!(b.get(attr::EDGE_FEATS).unwrap().shape(), &[40, 1]);
        // Feature column matches storage rows.
        assert_eq!(b.get(attr::EDGE_FEATS).unwrap().as_f32().unwrap()[0], 0.0);
    }

    #[test]
    fn split_views_iterate_consistently() {
        let d = data();
        let splits = d.split().unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("train").unwrap();
        let total: usize = [&splits.train, &splits.val, &splits.test]
            .iter()
            .map(|v| {
                let mut l = DGDataLoader::new((*v).clone(), BatchBy::Events(32), &mut m).unwrap();
                l.collect_all().unwrap().iter().map(|b| b.num_edges()).sum::<usize>()
            })
            .sum();
        assert_eq!(total, 120);
    }
}
