//! Optional CPU pinning for [`super::pool::ServingPool`] workers.
//!
//! When a tenant's sealed segments are mmap-served, the pages live in
//! the page cache of whichever socket faulted them; a worker that
//! migrates across sockets pays remote-node latency on every gather.
//! Pinning each pool worker to a fixed CPU keeps a tenant's workers on
//! the socket that owns its columns. Like the mmap/flock FFI next door
//! ([`crate::persist::mmap`]), the `sched_setaffinity(2)` declaration
//! is direct — no new dependencies — and compiled only on Linux;
//! everywhere else [`supported`] reports `false` and pinning is a
//! silent no-op (serving behavior is identical either way).
//!
//! Pinning is opt-in via the `TGM_PIN_WORKERS` env var:
//!
//! - unset / empty / `0` / `off` — no pinning (default);
//! - `auto` — worker `i` pins to CPU `i % available_parallelism`;
//! - a cpu list like `0-3,8,10-11` — worker `i` pins to the `i`-th
//!   listed CPU (wrapping around).

#[cfg(target_os = "linux")]
mod sys {
    use std::os::raw::{c_int, c_ulong};

    /// Matches glibc's fixed 1024-bit `cpu_set_t`.
    pub const CPU_SET_WORDS: usize = 1024 / (8 * std::mem::size_of::<c_ulong>());

    extern "C" {
        pub fn sched_setaffinity(pid: c_int, cpusetsize: usize, mask: *const c_ulong) -> c_int;
    }
}

/// True when this build can pin threads (Linux).
pub fn supported() -> bool {
    cfg!(target_os = "linux")
}

/// Pin the calling thread to `cpu`. Returns `true` on success; failures
/// (CPU offline, cpuset restrictions, unsupported platform) are
/// reported but never fatal — serving proceeds unpinned.
#[cfg(target_os = "linux")]
pub fn pin_current_thread(cpu: usize) -> bool {
    let mut mask: [std::os::raw::c_ulong; sys::CPU_SET_WORDS] = [0; sys::CPU_SET_WORDS];
    let bits = 8 * std::mem::size_of::<std::os::raw::c_ulong>();
    let (word, bit) = (cpu / bits, cpu % bits);
    if word >= mask.len() {
        return false;
    }
    mask[word] = 1 << bit;
    // Safety: pid 0 targets the calling thread; the mask buffer is a
    // valid, initialized cpu_set_t-sized allocation for the duration of
    // the call, and the kernel only reads it.
    let rc = unsafe { sys::sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) };
    rc == 0
}

/// Unsupported-platform stub.
#[cfg(not(target_os = "linux"))]
pub fn pin_current_thread(_cpu: usize) -> bool {
    false
}

/// Parse a Linux-style cpu list (`0-3,8,10-11`) into CPU ids. Malformed
/// parts are skipped; an empty result means "do not pin".
pub fn parse_cpu_list(list: &str) -> Vec<usize> {
    let mut cpus = Vec::new();
    for part in list.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((lo, hi)) = part.split_once('-') {
            if let (Ok(lo), Ok(hi)) = (lo.trim().parse::<usize>(), hi.trim().parse::<usize>()) {
                if lo <= hi && hi - lo < 4096 {
                    cpus.extend(lo..=hi);
                }
            }
        } else if let Ok(cpu) = part.parse::<usize>() {
            cpus.push(cpu);
        }
    }
    cpus
}

/// The pin plan requested via `TGM_PIN_WORKERS` (see module docs):
/// `None` when pinning is disabled or unsupported, else the CPU list
/// workers cycle through.
pub fn env_pin_plan() -> Option<Vec<usize>> {
    if !supported() {
        return None;
    }
    let raw = std::env::var("TGM_PIN_WORKERS").ok()?;
    let raw = raw.trim();
    if raw.is_empty() || raw == "0" || raw.eq_ignore_ascii_case("off") {
        return None;
    }
    let cpus = if raw.eq_ignore_ascii_case("auto") {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        (0..n).collect()
    } else {
        parse_cpu_list(raw)
    };
    if cpus.is_empty() {
        None
    } else {
        Some(cpus)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_lists_parse() {
        assert_eq!(parse_cpu_list("0-3,8"), vec![0, 1, 2, 3, 8]);
        assert_eq!(parse_cpu_list(" 1 , 4-5 "), vec![1, 4, 5]);
        assert_eq!(parse_cpu_list("7"), vec![7]);
        assert!(parse_cpu_list("").is_empty());
        assert!(parse_cpu_list("x,3-1,-2").is_empty());
    }

    #[test]
    fn pinning_to_cpu_zero_works_where_supported() {
        if !supported() {
            assert!(!pin_current_thread(0));
            return;
        }
        // CPU 0 exists on every Linux box this runs on; pin a scratch
        // thread rather than the test harness thread.
        let ok = std::thread::spawn(|| pin_current_thread(0)).join().unwrap();
        assert!(ok, "pinning a thread to CPU 0 should succeed");
        // Absurd CPU ids fail gracefully.
        assert!(!pin_current_thread(1 << 20));
    }
}
