//! Unified request scheduling: tenant-weighted fair queueing with
//! admission control for the shared [`super::ServingPool`].
//!
//! The pool used to be a single FIFO: every tenant's batch jobs landed
//! in one queue, so one tenant's epoch scan queued ahead of everyone
//! else's small reads. This module lifts the job model into a request
//! abstraction the pool schedules explicitly:
//!
//! * every request carries a [`QosTag`] — tenant, [`RequestClass`]
//!   (point query vs batch scan), scheduling weight, and an admission
//!   cap;
//! * a [`Scheduler`] decides service order. The default
//!   [`DrrScheduler`] runs **weighted deficit round robin** over
//!   per-`(tenant, class)` queues: each nonempty queue gets
//!   `weight × quantum` credit per round and serves requests while its
//!   credit covers their [cost](SchedEntry::cost). Point queries cost
//!   [`POINT_COST`], batch jobs [`BATCH_COST`], so under equal weights a
//!   tenant's point class is served [`BATCH_COST`]`/`[`POINT_COST`]
//!   requests for every scan — and because every nonempty queue is
//!   visited every round, a backlog of scans can never starve another
//!   queue (bounded-delay fairness, not just proportional share);
//! * **admission control** sits in front: a queue at its
//!   [`QosTag::max_queued`] cap rejects the enqueue with the existing
//!   typed [`TgmError::Backpressure`], so an over-driving tenant sheds
//!   its own load instead of growing everyone's queue.
//!
//! `TGM_QOS=fifo` falls back to the legacy single-FIFO order (admission
//! caps still apply); `TGM_QOS_DEPTH` overrides the default per-queue
//! admission cap. Scheduling never changes *results* — batches stay
//! byte-identical and plan-ordered per stream — only service order
//! across tenants.

use crate::error::{Result, TgmError};
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

/// Deficit units charged per point query.
pub const POINT_COST: u32 = 1;

/// Deficit units charged per batch-materialization job (a batch arena +
/// stateless hook phase is orders of magnitude more work than a point
/// read).
pub const BATCH_COST: u32 = 4;

/// Credit added to a queue per round visit, scaled by its weight. Equal
/// to [`BATCH_COST`], so a weight-1 queue serves at least one request
/// (of any class) per round — the starvation-freedom bound.
const QUANTUM: u64 = BATCH_COST as u64;

/// Default per-`(tenant, class)` admission cap when the tag does not
/// set one (overridable via `TGM_QOS_DEPTH`).
pub const DEFAULT_MAX_QUEUED: usize = 1024;

/// Request class: what shape of work a queue entry is.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum RequestClass {
    /// A small read on a pinned snapshot (see [`crate::graph::point`]).
    PointQuery,
    /// One batch-materialization job of a pooled stream.
    BatchScan,
}

impl RequestClass {
    /// Stable label for stats/profiler rows.
    pub fn label(self) -> &'static str {
        match self {
            RequestClass::PointQuery => "point",
            RequestClass::BatchScan => "scan",
        }
    }

    /// Deficit cost of one request of this class.
    pub fn cost(self) -> u32 {
        match self {
            RequestClass::PointQuery => POINT_COST,
            RequestClass::BatchScan => BATCH_COST,
        }
    }
}

/// Scheduling identity of a request: which per-tenant class queue it
/// joins, with what weight and admission cap.
#[derive(Debug, Clone)]
pub struct QosTag {
    /// Tenant key (shared cheaply across requests).
    pub tenant: Arc<str>,
    /// Request class.
    pub class: RequestClass,
    /// Relative service share (clamped to `1..=1024`). Completed-request
    /// ratios between saturated equal-cost queues converge to the
    /// weight ratio.
    pub weight: u32,
    /// Admission cap: an enqueue finding this many requests already
    /// queued in the same `(tenant, class)` queue fails with
    /// [`TgmError::Backpressure`].
    pub max_queued: usize,
}

impl QosTag {
    /// Tag for `tenant` with explicit weight and the default admission
    /// cap (`TGM_QOS_DEPTH` or [`DEFAULT_MAX_QUEUED`]).
    pub fn new(tenant: impl AsRef<str>, class: RequestClass, weight: u32) -> QosTag {
        QosTag {
            tenant: Arc::from(tenant.as_ref()),
            class,
            weight: weight.clamp(1, 1024),
            max_queued: env_default_depth(),
        }
    }

    /// Override the admission cap.
    pub fn with_max_queued(mut self, cap: usize) -> QosTag {
        self.max_queued = cap.max(1);
        self
    }

    /// The tag anonymous batch streams run under (weight 1, effectively
    /// uncapped — their sliding window already bounds in-flight jobs).
    pub fn shared_batch() -> QosTag {
        QosTag {
            tenant: Arc::from(""),
            class: RequestClass::BatchScan,
            weight: 1,
            max_queued: usize::MAX,
        }
    }

    fn key(&self) -> (Arc<str>, RequestClass) {
        (Arc::clone(&self.tenant), self.class)
    }
}

impl Default for QosTag {
    fn default() -> QosTag {
        QosTag::shared_batch()
    }
}

/// One scheduled request: its tag, deficit cost, enqueue instant (for
/// per-class latency histograms) and opaque payload.
pub struct SchedEntry<T> {
    /// Scheduling identity.
    pub tag: QosTag,
    /// Deficit units this request consumes when served.
    pub cost: u32,
    /// When the request entered the queue.
    pub enqueued: Instant,
    /// The work itself (the pool's job enum).
    pub payload: T,
}

/// Service-order policy over [`SchedEntry`]s. Implementations must be
/// work-conserving: `dequeue` returns `Some` whenever `len() > 0`.
pub trait Scheduler<T>: Send {
    /// Admit a request, or reject it with [`TgmError::Backpressure`]
    /// when its `(tenant, class)` queue is at its admission cap.
    fn enqueue(&mut self, entry: SchedEntry<T>) -> Result<()>;

    /// Next request in service order (`None` when idle).
    fn dequeue(&mut self) -> Option<SchedEntry<T>>;

    /// Requests currently queued.
    fn len(&self) -> usize;

    /// True when no request is queued.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Which scheduler the pool builds (from `TGM_QOS`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedulerKind {
    /// Weighted deficit round robin (the default).
    #[default]
    WeightedDrr,
    /// Legacy single FIFO (admission caps still enforced).
    Fifo,
}

impl SchedulerKind {
    /// `TGM_QOS=fifo` selects the legacy FIFO; anything else (or unset)
    /// selects weighted DRR.
    pub fn from_env() -> SchedulerKind {
        match std::env::var("TGM_QOS") {
            Ok(v) if v.trim().eq_ignore_ascii_case("fifo") => SchedulerKind::Fifo,
            _ => SchedulerKind::WeightedDrr,
        }
    }

    /// Build a boxed scheduler of this kind.
    pub fn build<T: Send + 'static>(self) -> Box<dyn Scheduler<T>> {
        match self {
            SchedulerKind::WeightedDrr => Box::new(DrrScheduler::new()),
            SchedulerKind::Fifo => Box::new(FifoScheduler::new()),
        }
    }
}

/// Default admission cap: `TGM_QOS_DEPTH` or [`DEFAULT_MAX_QUEUED`].
fn env_default_depth() -> usize {
    std::env::var("TGM_QOS_DEPTH")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(DEFAULT_MAX_QUEUED)
}

fn backpressure(tag: &QosTag, queued: usize) -> TgmError {
    // Registration is cheap relative to shedding load, and rejections
    // are off the hot path by definition.
    crate::obs::registry()
        .counter(
            "tgm_admission_rejections_total",
            &[
                ("tenant", crate::obs::Label::from(&tag.tenant)),
                ("class", crate::obs::Label::from(tag.class.label())),
            ],
        )
        .inc();
    TgmError::Backpressure(format!(
        "tenant `{}` {} queue is at its admission cap ({queued} queued); \
         retry after in-flight requests drain or raise the cap",
        tag.tenant,
        tag.class.label(),
    ))
}

/// Legacy service order: one FIFO across all tenants and classes, with
/// per-queue admission caps still enforced.
pub struct FifoScheduler<T> {
    items: VecDeque<SchedEntry<T>>,
    queued: HashMap<(Arc<str>, RequestClass), usize>,
}

impl<T> FifoScheduler<T> {
    /// Empty scheduler.
    pub fn new() -> FifoScheduler<T> {
        FifoScheduler { items: VecDeque::new(), queued: HashMap::new() }
    }
}

impl<T> Default for FifoScheduler<T> {
    fn default() -> Self {
        FifoScheduler::new()
    }
}

impl<T: Send> Scheduler<T> for FifoScheduler<T> {
    fn enqueue(&mut self, entry: SchedEntry<T>) -> Result<()> {
        let count = self.queued.entry(entry.tag.key()).or_insert(0);
        if *count >= entry.tag.max_queued {
            return Err(backpressure(&entry.tag, *count));
        }
        *count += 1;
        self.items.push_back(entry);
        Ok(())
    }

    fn dequeue(&mut self) -> Option<SchedEntry<T>> {
        let entry = self.items.pop_front()?;
        if let Some(c) = self.queued.get_mut(&entry.tag.key()) {
            *c -= 1;
        }
        Some(entry)
    }

    fn len(&self) -> usize {
        self.items.len()
    }
}

/// One `(tenant, class)` queue of the DRR scheduler (keyed externally
/// by the scheduler's index map).
struct ClassQueue<T> {
    /// Latest weight seen on an enqueue (tenant reconfiguration applies
    /// from the next round).
    weight: u32,
    deficit: u64,
    items: VecDeque<SchedEntry<T>>,
    /// True while the queue index sits in the active ring.
    in_ring: bool,
}

/// Weighted deficit round robin over per-`(tenant, class)` queues.
///
/// Properties (pinned by the fairness tests):
/// * **proportional share**: saturated equal-cost queues complete
///   requests in their weight ratio;
/// * **starvation-freedom**: every nonempty queue is visited once per
///   round and a visit's credit (`weight × QUANTUM ≥ BATCH_COST`)
///   always covers at least one request, so the worst-case delay of a
///   point query is one round — independent of any batch backlog depth.
pub struct DrrScheduler<T> {
    queues: Vec<ClassQueue<T>>,
    index: HashMap<(Arc<str>, RequestClass), usize>,
    /// Round-robin ring of nonempty queue indices (excluding `current`).
    ring: VecDeque<usize>,
    /// Queue currently spending its deficit, if any.
    current: Option<usize>,
    len: usize,
}

impl<T> DrrScheduler<T> {
    /// Empty scheduler.
    pub fn new() -> DrrScheduler<T> {
        DrrScheduler {
            queues: Vec::new(),
            index: HashMap::new(),
            ring: VecDeque::new(),
            current: None,
            len: 0,
        }
    }
}

impl<T> Default for DrrScheduler<T> {
    fn default() -> Self {
        DrrScheduler::new()
    }
}

impl<T: Send> Scheduler<T> for DrrScheduler<T> {
    fn enqueue(&mut self, entry: SchedEntry<T>) -> Result<()> {
        let idx = match self.index.get(&entry.tag.key()) {
            Some(&i) => i,
            None => {
                let i = self.queues.len();
                self.queues.push(ClassQueue {
                    weight: entry.tag.weight.clamp(1, 1024),
                    deficit: 0,
                    items: VecDeque::new(),
                    in_ring: false,
                });
                self.index.insert(entry.tag.key(), i);
                i
            }
        };
        let q = &mut self.queues[idx];
        if q.items.len() >= entry.tag.max_queued {
            return Err(backpressure(&entry.tag, q.items.len()));
        }
        q.weight = entry.tag.weight.clamp(1, 1024);
        q.items.push_back(entry);
        if !q.in_ring && self.current != Some(idx) {
            q.in_ring = true;
            self.ring.push_back(idx);
        }
        self.len += 1;
        Ok(())
    }

    fn dequeue(&mut self) -> Option<SchedEntry<T>> {
        loop {
            let idx = match self.current {
                Some(i) => i,
                None => {
                    let i = self.ring.pop_front()?;
                    let q = &mut self.queues[i];
                    q.in_ring = false;
                    // One round's credit on entering service.
                    q.deficit = q.deficit.saturating_add(q.weight as u64 * QUANTUM);
                    self.current = Some(i);
                    i
                }
            };
            let q = &mut self.queues[idx];
            let Some(head_cost) = q.items.front().map(|e| e.cost.max(1) as u64) else {
                // Drained while current (or a spurious ring entry):
                // forfeit unused credit so idle queues cannot bank it.
                q.deficit = 0;
                self.current = None;
                continue;
            };
            if head_cost <= q.deficit {
                q.deficit -= head_cost;
                let entry = q.items.pop_front();
                self.len -= 1;
                if q.items.is_empty() {
                    q.deficit = 0;
                    self.current = None;
                }
                return entry;
            }
            // Credit exhausted: back of the ring, keep the remainder.
            self.current = None;
            q.in_ring = true;
            self.ring.push_back(idx);
        }
    }

    fn len(&self) -> usize {
        self.len
    }
}

/// Fixed log₂-bucketed latency histogram (microseconds). Coarse by
/// design — it answers "what order of magnitude is p99" for the
/// profiler and pool stats without unbounded memory; benches wanting
/// exact percentiles keep their own samples.
#[derive(Debug, Clone, Default)]
pub struct LatencyHistogram {
    /// `counts[i]` holds samples with `floor(log2(us + 1)) == i`.
    counts: [u64; 40],
    total: u64,
    sum_us: u64,
    max_us: u64,
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> LatencyHistogram {
        LatencyHistogram::default()
    }

    /// Rebuild a histogram from raw parts — the bridge from the atomic
    /// registry histograms in [`crate::obs`], which share this exact
    /// bucket layout.
    pub fn from_parts(counts: [u64; 40], total: u64, sum_us: u64, max_us: u64) -> LatencyHistogram {
        LatencyHistogram { counts, total, sum_us, max_us }
    }

    /// Raw per-bucket counts (`counts[i]` holds samples with
    /// `floor(log2(us + 1)) == i`).
    pub fn bucket_counts(&self) -> &[u64; 40] {
        &self.counts
    }

    /// Sum of all recorded samples in microseconds.
    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    /// Record one latency sample in microseconds.
    pub fn record_us(&mut self, us: u64) {
        let bucket =
            (64 - us.saturating_add(1).leading_zeros() as usize - 1).min(self.counts.len() - 1);
        self.counts[bucket] += 1;
        self.total += 1;
        self.sum_us = self.sum_us.saturating_add(us);
        self.max_us = self.max_us.max(us);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// True when no sample was recorded.
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Mean latency in microseconds (0 when empty).
    pub fn mean_us(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.sum_us / self.total
        }
    }

    /// Largest recorded sample.
    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Upper bound of the bucket holding the `p`-th percentile sample
    /// (`p` in `0..=100`); 0 when empty. Within 2x of the exact value by
    /// construction.
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((p.clamp(0.0, 100.0) / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                // Bucket i holds samples in [2^i - 1, 2^(i+1) - 2]; the
                // max clamps the final (open-ended) bucket.
                return ((1u64 << (i + 1)) - 2).min(self.max_us.max(1));
            }
        }
        self.max_us
    }

    /// Fold another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum_us = self.sum_us.saturating_add(other.sum_us);
        self.max_us = self.max_us.max(other.max_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(tenant: &str, class: RequestClass, weight: u32, cap: usize) -> SchedEntry<u32> {
        SchedEntry {
            tag: QosTag::new(tenant, class, weight).with_max_queued(cap),
            cost: class.cost(),
            enqueued: Instant::now(),
            payload: 0,
        }
    }

    #[test]
    fn fifo_preserves_order_and_enforces_caps() {
        let mut s: FifoScheduler<u32> = FifoScheduler::new();
        for i in 0..3u32 {
            let mut e = entry("a", RequestClass::BatchScan, 1, 3);
            e.payload = i;
            s.enqueue(e).unwrap();
        }
        let err = s.enqueue(entry("a", RequestClass::BatchScan, 1, 3)).unwrap_err();
        assert!(matches!(err, TgmError::Backpressure(_)), "{err}");
        // A different class of the same tenant has its own cap.
        s.enqueue(entry("a", RequestClass::PointQuery, 1, 3)).unwrap();
        let order: Vec<u32> = std::iter::from_fn(|| s.dequeue()).map(|e| e.payload).collect();
        assert_eq!(order, vec![0, 1, 2, 0]);
        assert!(s.is_empty());
        // Draining freed the cap.
        s.enqueue(entry("a", RequestClass::BatchScan, 1, 3)).unwrap();
    }

    /// Saturating two-tenant load: keep both queues topped up, count
    /// completions per tenant, and require the ratio to converge to the
    /// weight ratio within 10% — across several weight pairs and both
    /// request classes (the property the ISSUE names).
    #[test]
    fn drr_completed_ratio_converges_to_weight_ratio() {
        for (wa, wb) in [(1u32, 3u32), (1, 1), (2, 5), (1, 8)] {
            for class in [RequestClass::PointQuery, RequestClass::BatchScan] {
                let mut s: DrrScheduler<u32> = DrrScheduler::new();
                let top_up = |s: &mut DrrScheduler<u32>| {
                    for (t, w) in [("a", wa), ("b", wb)] {
                        // Saturation: both queues always hold work.
                        while s
                            .index
                            .get(&(Arc::from(t), class))
                            .map(|&i| s.queues[i].items.len())
                            .unwrap_or(0)
                            < 4
                        {
                            s.enqueue(entry(t, class, w, usize::MAX)).unwrap();
                        }
                    }
                };
                let (mut got_a, mut got_b) = (0u64, 0u64);
                for _ in 0..4000 {
                    top_up(&mut s);
                    match s.dequeue().unwrap().tag.tenant.as_ref() {
                        "a" => got_a += 1,
                        _ => got_b += 1,
                    }
                }
                let ratio = got_b as f64 / got_a as f64;
                let want = wb as f64 / wa as f64;
                assert!(
                    (ratio - want).abs() / want < 0.10,
                    "weights ({wa},{wb}) {class:?}: completed ratio {ratio:.3}, want {want:.3}"
                );
            }
        }
    }

    /// A point query behind an arbitrarily deep batch backlog of another
    /// tenant is served within one DRR round, never starved.
    #[test]
    fn drr_never_starves_point_queries_behind_batch_backlog() {
        let mut s: DrrScheduler<u32> = DrrScheduler::new();
        for _ in 0..500 {
            s.enqueue(entry("scanner", RequestClass::BatchScan, 8, usize::MAX)).unwrap();
        }
        s.enqueue(entry("reader", RequestClass::PointQuery, 1, usize::MAX)).unwrap();
        // Worst case: the scanner finishes its whole round's credit
        // (weight 8 → 8 batch jobs) before the reader's visit.
        let mut served_after = 0usize;
        loop {
            let e = s.dequeue().unwrap();
            if e.tag.class == RequestClass::PointQuery {
                break;
            }
            served_after += 1;
            assert!(served_after <= 8, "point query starved behind {served_after} batch jobs");
        }
    }

    #[test]
    fn drr_mixed_classes_within_one_tenant_favor_points_by_cost() {
        // Equal weights, same tenant: per round the point queue serves
        // BATCH_COST/POINT_COST times as many requests as the scan queue.
        let mut s: DrrScheduler<u32> = DrrScheduler::new();
        for _ in 0..400 {
            s.enqueue(entry("t", RequestClass::PointQuery, 1, usize::MAX)).unwrap();
            s.enqueue(entry("t", RequestClass::BatchScan, 1, usize::MAX)).unwrap();
        }
        let (mut points, mut scans) = (0u64, 0u64);
        for _ in 0..200 {
            match s.dequeue().unwrap().tag.class {
                RequestClass::PointQuery => points += 1,
                RequestClass::BatchScan => scans += 1,
            }
        }
        let ratio = points as f64 / scans as f64;
        let want = (BATCH_COST / POINT_COST) as f64;
        assert!((ratio - want).abs() / want < 0.15, "point/scan ratio {ratio:.2}, want {want}");
    }

    #[test]
    fn drr_admission_cap_returns_backpressure_per_queue() {
        let mut s: DrrScheduler<u32> = DrrScheduler::new();
        for _ in 0..2 {
            s.enqueue(entry("a", RequestClass::PointQuery, 1, 2)).unwrap();
        }
        let err = s.enqueue(entry("a", RequestClass::PointQuery, 1, 2)).unwrap_err();
        assert!(matches!(err, TgmError::Backpressure(_)), "{err}");
        assert!(err.to_string().contains("admission cap"), "{err}");
        // Other queues are unaffected by one tenant's full queue.
        s.enqueue(entry("b", RequestClass::PointQuery, 1, 2)).unwrap();
        s.enqueue(entry("a", RequestClass::BatchScan, 1, 2)).unwrap();
        assert_eq!(s.len(), 4);
        // Serving drains the cap.
        while s.dequeue().is_some() {}
        s.enqueue(entry("a", RequestClass::PointQuery, 1, 2)).unwrap();
    }

    #[test]
    fn drr_is_work_conserving() {
        let mut s: DrrScheduler<u32> = DrrScheduler::new();
        // Interleave enqueues/dequeues across tenants with odd weights;
        // every dequeue must produce work while len > 0.
        for round in 0..50u32 {
            for (t, w) in [("x", 1), ("y", 7), ("z", 3)] {
                s.enqueue(entry(t, RequestClass::BatchScan, w, usize::MAX)).unwrap();
                if round % 3 == 0 {
                    s.enqueue(entry(t, RequestClass::PointQuery, w, usize::MAX)).unwrap();
                }
            }
            if round % 2 == 0 {
                assert!(s.dequeue().is_some());
            }
        }
        let mut drained = 0;
        while !s.is_empty() {
            assert!(s.dequeue().is_some(), "work-conservation violated with {} queued", s.len());
            drained += 1;
        }
        assert!(drained > 0);
        assert!(s.dequeue().is_none());
    }

    #[test]
    fn scheduler_kind_builds_both() {
        let mut drr = SchedulerKind::WeightedDrr.build::<u32>();
        let mut fifo = SchedulerKind::Fifo.build::<u32>();
        drr.enqueue(entry("a", RequestClass::PointQuery, 1, 8)).unwrap();
        fifo.enqueue(entry("a", RequestClass::PointQuery, 1, 8)).unwrap();
        assert_eq!(drr.len(), 1);
        assert_eq!(fifo.len(), 1);
        assert!(drr.dequeue().is_some() && fifo.dequeue().is_some());
    }

    #[test]
    fn latency_histogram_percentiles_and_merge() {
        let mut h = LatencyHistogram::new();
        assert_eq!(h.percentile_us(99.0), 0);
        for us in [10u64, 12, 14, 100, 5000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.mean_us(), (10 + 12 + 14 + 100 + 5000) / 5);
        assert_eq!(h.max_us(), 5000);
        // Log-bucketed: within 2x of the exact percentile, monotone.
        let p50 = h.percentile_us(50.0);
        let p99 = h.percentile_us(99.0);
        assert!((12..=30).contains(&p50), "p50 {p50}");
        assert!((5000..=10000).contains(&p99), "p99 {p99}");
        assert!(h.percentile_us(0.0) <= p50 && p50 <= p99);

        let mut other = LatencyHistogram::new();
        other.record_us(7);
        other.merge(&h);
        assert_eq!(other.count(), 6);
        assert_eq!(other.max_us(), 5000);
    }
}
