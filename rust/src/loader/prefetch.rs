//! Parallel, double-buffered batch materialization.
//!
//! [`PrefetchLoader`] executes the same [`super::BatchPlan`] the serial
//! [`super::DGDataLoader`] would, but pipelines it:
//!
//! * a small pool of **worker threads** pulls plan indices from a shared
//!   counter, materializes seed columns ([`super::materialize_window`])
//!   and applies the *stateless* hook phase
//!   ([`crate::hooks::StatelessPipeline`]), then pushes the batch into a
//!   **bounded channel** (backpressure keeps memory proportional to the
//!   queue depth, not the epoch);
//! * the consumer reorders arrivals back into plan order (workers may
//!   finish out of order) and applies the *stateful* hook phase via
//!   [`crate::hooks::HookManager::run_stateful_indexed`], so hooks like
//!   the recency sampler still observe batches strictly in order.
//!
//! **Determinism guarantee.** For any worker count, the yielded batches
//! are byte-identical to the serial loader's: batch boundaries come from
//! the shared plan, stateless hooks draw per-batch RNG streams seeded by
//! the plan index (not a shared generator), and the stateful phase runs
//! in plan order on one thread. The `ablation.prefetch` bench tracks the
//! wall-clock win; the tests in this module pin the equality.

use crate::error::{Result, TgmError};
use crate::graph::{DGraph, StorageSnapshot};
use crate::hooks::batch::MaterializedBatch;
use crate::hooks::manager::{HookManager, StatelessPipeline};
use crate::loader::{materialize_window, plan_batches, BatchBy, BatchPlan};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// One worker-to-consumer message: plan position plus the materialized
/// batch (or the error that produced it).
type WorkerMsg = (usize, Result<MaterializedBatch>);

/// Prefetch pipeline configuration.
#[derive(Debug, Clone)]
pub struct PrefetchConfig {
    /// Worker threads materializing batches. `0` degrades to a serial
    /// in-place pipeline (no threads, same output).
    pub workers: usize,
    /// Bounded channel capacity: how many finished batches may wait
    /// ahead of the consumer.
    pub queue_depth: usize,
    /// Skip empty time buckets (mirrors the serial loader's default).
    pub skip_empty: bool,
    /// Max events per time-iteration batch (see
    /// [`super::DGDataLoader::with_event_cap`]).
    pub event_cap: usize,
}

impl Default for PrefetchConfig {
    fn default() -> Self {
        PrefetchConfig { workers: 2, queue_depth: 4, skip_empty: true, event_cap: usize::MAX }
    }
}

impl PrefetchConfig {
    /// Set the worker count.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Set the bounded queue depth.
    pub fn with_queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth.max(1);
        self
    }

    /// Keep empty time buckets.
    pub fn with_empty_batches(mut self) -> Self {
        self.skip_empty = false;
        self
    }

    /// Split oversized time buckets to at most `cap` events.
    pub fn with_event_cap(mut self, cap: usize) -> Self {
        self.event_cap = cap.max(1);
        self
    }
}

/// Wall-clock accounting for the overlap report (Table 11 extension).
#[derive(Debug, Clone, Default)]
pub struct PrefetchStats {
    /// Total planned batches.
    pub batches: usize,
    /// Worker threads used (0 = serial fallback).
    pub workers: usize,
    /// Sum of worker time spent materializing + running stateless hooks.
    /// With overlap, most of this hides behind consumer compute.
    pub worker_busy: Duration,
    /// Time the consumer actually waited on the channel — the part of
    /// the materialization cost that leaked into the critical path.
    pub consumer_blocked: Duration,
}

/// Loader that materializes batches on a worker pool and yields them in
/// plan order with the stateful hook phase applied.
pub struct PrefetchLoader<'a> {
    manager: &'a mut HookManager,
    storage: Arc<StorageSnapshot>,
    plans: Arc<Vec<BatchPlan>>,
    /// Serial fallback pipeline when `workers == 0`.
    inline: Option<StatelessPipeline>,
    rx: Option<Receiver<WorkerMsg>>,
    /// Reorder buffer for batches that arrived ahead of plan order.
    pending: HashMap<usize, Result<MaterializedBatch>>,
    next_index: usize,
    handles: Vec<thread::JoinHandle<()>>,
    busy: Arc<Mutex<Duration>>,
    blocked: Duration,
    workers: usize,
    /// Manager registration epoch at snapshot time; a mismatch on
    /// `next()` means hooks were registered mid-iteration and the worker
    /// snapshot no longer reflects the recipe.
    epoch: u64,
}

impl<'a> PrefetchLoader<'a> {
    /// Plan the iteration, snapshot the active recipe's stateless phase,
    /// and launch the worker pool. The manager must be activated first
    /// (same contract as [`super::DGDataLoader`] + `HookManager::run`).
    pub fn new(
        view: DGraph,
        by: BatchBy,
        manager: &'a mut HookManager,
        cfg: PrefetchConfig,
    ) -> Result<PrefetchLoader<'a>> {
        let plans = Arc::new(plan_batches(&view, by, cfg.skip_empty, cfg.event_cap)?);
        let pipeline = manager.stateless_pipeline()?;
        let epoch = manager.registration_epoch();
        let storage = Arc::clone(view.storage());
        let busy = Arc::new(Mutex::new(Duration::ZERO));
        let workers = if plans.is_empty() { 0 } else { cfg.workers };

        let mut handles = Vec::new();
        let rx = if workers == 0 {
            None
        } else {
            let (tx, rx) = sync_channel::<WorkerMsg>(cfg.queue_depth.max(workers));
            let counter = Arc::new(AtomicUsize::new(0));
            for _ in 0..workers {
                let plans = Arc::clone(&plans);
                let storage = Arc::clone(&storage);
                let pipeline = pipeline.clone();
                let counter = Arc::clone(&counter);
                let busy = Arc::clone(&busy);
                let tx = tx.clone();
                handles.push(thread::spawn(move || loop {
                    let i = counter.fetch_add(1, Ordering::Relaxed);
                    if i >= plans.len() {
                        break;
                    }
                    let t0 = Instant::now();
                    let plan = &plans[i];
                    let res = materialize_window(&storage, plan).and_then(|mut b| {
                        pipeline.run(&mut b, &storage, plan.index)?;
                        Ok(b)
                    });
                    if let Ok(mut d) = busy.lock() {
                        *d += t0.elapsed();
                    }
                    // A closed channel means the consumer is gone: stop.
                    if tx.send((i, res)).is_err() {
                        break;
                    }
                }));
            }
            // `tx` drops here; only workers hold senders, so `recv`
            // disconnects exactly when the pool drains or dies.
            Some(rx)
        };

        Ok(PrefetchLoader {
            manager,
            storage,
            plans,
            inline: if workers == 0 { Some(pipeline) } else { None },
            rx,
            pending: HashMap::new(),
            next_index: 0,
            handles,
            busy,
            blocked: Duration::ZERO,
            workers,
            epoch,
        })
    }

    /// Exact number of batches remaining.
    pub fn num_batches_hint(&self) -> usize {
        self.plans.len() - self.next_index
    }

    /// Overlap accounting so far (read after draining for epoch totals).
    pub fn stats(&self) -> PrefetchStats {
        PrefetchStats {
            batches: self.plans.len(),
            workers: self.workers,
            worker_busy: *self.busy.lock().unwrap_or_else(|e| e.into_inner()),
            consumer_blocked: self.blocked,
        }
    }

    /// Next batch in plan order, or `None` when exhausted.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Option<Result<MaterializedBatch>> {
        if self.next_index >= self.plans.len() {
            return None;
        }
        // The worker pipeline is a point-in-time snapshot of the recipe;
        // registering hooks mid-iteration would silently diverge from the
        // serial loader, so fail loudly — and terminate the stream, like
        // the serial loader's poisoned plan, so error-tolerant consumers
        // cannot spin on a sticky error.
        if self.manager.registration_epoch() != self.epoch {
            self.next_index = self.plans.len();
            return Some(Err(TgmError::Hook(
                "hooks were registered while a prefetch iteration was in flight; \
                 recreate the loader to pick them up"
                    .into(),
            )));
        }
        let idx = self.next_index;
        self.next_index += 1;

        // Serial fallback: materialize inline, no threads involved.
        if self.inline.is_some() {
            let plan = self.plans[idx].clone();
            let mut batch = match materialize_window(&self.storage, &plan) {
                Ok(b) => b,
                Err(e) => return Some(Err(e)),
            };
            if let Some(pipeline) = &self.inline {
                if let Err(e) = pipeline.run(&mut batch, &self.storage, plan.index) {
                    return Some(Err(e));
                }
            }
            if let Err(e) = self.manager.run_stateful_indexed(&mut batch, &self.storage, plan.index)
            {
                return Some(Err(e));
            }
            return Some(Ok(batch));
        }

        // Pull from the pool, reordering into plan order.
        let t0 = Instant::now();
        let res = loop {
            if let Some(r) = self.pending.remove(&idx) {
                break r;
            }
            let rx = self.rx.as_ref().expect("prefetch pool missing");
            match rx.recv() {
                Ok((i, r)) => {
                    if i == idx {
                        break r;
                    }
                    self.pending.insert(i, r);
                }
                Err(_) => {
                    break Err(TgmError::Hook(
                        "prefetch worker pool terminated unexpectedly (worker panic?)".into(),
                    ))
                }
            }
        };
        self.blocked += t0.elapsed();

        match res {
            Ok(mut batch) => {
                let plan_index = self.plans[idx].index;
                if let Err(e) =
                    self.manager.run_stateful_indexed(&mut batch, &self.storage, plan_index)
                {
                    return Some(Err(e));
                }
                Some(Ok(batch))
            }
            Err(e) => Some(Err(e)),
        }
    }

    /// Drain all remaining batches.
    pub fn collect_all(&mut self) -> Result<Vec<MaterializedBatch>> {
        let mut out = Vec::new();
        while let Some(b) = self.next() {
            out.push(b?);
        }
        Ok(out)
    }
}

impl Drop for PrefetchLoader<'_> {
    fn drop(&mut self) {
        // Closing the receiver makes any blocked `send` fail, so workers
        // exit promptly even mid-epoch; then reap them.
        self.rx.take();
        self.pending.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hooks::recipes::{RecipeConfig, RecipeRegistry, SamplerKind, RECIPE_TGB_LINK};
    use crate::io::gen;
    use crate::loader::DGDataLoader;
    use crate::util::TimeGranularity;

    /// Full structural equality: seed columns, windows, and every
    /// attribute tensor byte-for-byte.
    fn assert_batches_identical(serial: &[MaterializedBatch], prefetched: &[MaterializedBatch]) {
        assert_eq!(serial.len(), prefetched.len(), "batch counts differ");
        for (i, (a, b)) in serial.iter().zip(prefetched).enumerate() {
            assert_eq!(a.start, b.start, "batch {i} window start");
            assert_eq!(a.end, b.end, "batch {i} window end");
            assert_eq!(a.src, b.src, "batch {i} src");
            assert_eq!(a.dst, b.dst, "batch {i} dst");
            assert_eq!(a.ts, b.ts, "batch {i} ts");
            assert_eq!(a.edge_indices, b.edge_indices, "batch {i} edge indices");
            assert_eq!(a.node_events, b.node_events, "batch {i} node events");
            assert_eq!(a.attr_names(), b.attr_names(), "batch {i} attribute sets");
            for name in a.attr_names() {
                assert_eq!(
                    a.get(name).unwrap(),
                    b.get(name).unwrap(),
                    "batch {i} attribute `{name}` differs"
                );
            }
        }
    }

    fn serial_batches(key: &str, by: BatchBy, cap: usize) -> Vec<MaterializedBatch> {
        let data = gen::by_name("wiki", 0.05, 1).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate(key).unwrap();
        let mut l = DGDataLoader::new(data.full(), by, &mut m).unwrap().with_event_cap(cap);
        l.collect_all().unwrap()
    }

    fn prefetch_batches(key: &str, by: BatchBy, cap: usize, workers: usize) -> Vec<MaterializedBatch> {
        let data = gen::by_name("wiki", 0.05, 1).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate(key).unwrap();
        let cfg = PrefetchConfig::default().with_workers(workers).with_event_cap(cap);
        let mut l = PrefetchLoader::new(data.full(), by, &mut m, cfg).unwrap();
        l.collect_all().unwrap()
    }

    #[test]
    fn prefetch_matches_serial_for_event_batches() {
        // "train" exercises the mixed pipeline: stateless negatives on
        // workers + the stateful recency sampler on the consumer.
        // "val" exercises an all-stateless pipeline.
        let by = BatchBy::Events(100);
        for key in ["train", "val"] {
            let serial = serial_batches(key, by, usize::MAX);
            assert!(serial.len() >= 4, "want a multi-batch run, got {}", serial.len());
            for workers in [2, 4] {
                let pre = prefetch_batches(key, by, usize::MAX, workers);
                assert_batches_identical(&serial, &pre);
            }
        }
    }

    #[test]
    fn prefetch_matches_serial_for_time_batches() {
        let by = BatchBy::Time(TimeGranularity::Day);
        for key in ["train", "val"] {
            let serial = serial_batches(key, by, 150);
            assert!(serial.len() >= 4, "want a multi-batch run, got {}", serial.len());
            let pre = prefetch_batches(key, by, 150, 3);
            assert_batches_identical(&serial, &pre);
        }
    }

    #[test]
    fn prefetch_matches_serial_with_uniform_sampler() {
        // The uniform sampler is RNG-heavy and stateless: per-batch
        // seeding must reproduce the serial draw order exactly.
        let data = gen::by_name("wiki", 0.05, 2).unwrap();
        let cfg = RecipeConfig { sampler: SamplerKind::Uniform, ..Default::default() };
        let mut m1 = RecipeRegistry::build_with(RECIPE_TGB_LINK, &cfg).unwrap();
        m1.activate("train").unwrap();
        let mut l1 = DGDataLoader::new(data.full(), BatchBy::Events(64), &mut m1).unwrap();
        let serial = l1.collect_all().unwrap();

        let mut m2 = RecipeRegistry::build_with(RECIPE_TGB_LINK, &cfg).unwrap();
        m2.activate("train").unwrap();
        let mut l2 = PrefetchLoader::new(
            data.full(),
            BatchBy::Events(64),
            &mut m2,
            PrefetchConfig::default().with_workers(4).with_queue_depth(2),
        )
        .unwrap();
        let pre = l2.collect_all().unwrap();
        assert_batches_identical(&serial, &pre);
    }

    #[test]
    fn zero_workers_is_a_serial_pipeline() {
        let serial = serial_batches("val", BatchBy::Events(100), usize::MAX);
        let pre = prefetch_batches("val", BatchBy::Events(100), usize::MAX, 0);
        assert_batches_identical(&serial, &pre);
    }

    #[test]
    fn stats_account_worker_time() {
        let data = gen::by_name("wiki", 0.05, 1).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("val").unwrap();
        let mut l = PrefetchLoader::new(
            data.full(),
            BatchBy::Events(100),
            &mut m,
            PrefetchConfig::default().with_workers(2),
        )
        .unwrap();
        let n = l.num_batches_hint();
        let batches = l.collect_all().unwrap();
        assert_eq!(batches.len(), n);
        let stats = l.stats();
        assert_eq!(stats.batches, n);
        assert_eq!(stats.workers, 2);
        assert!(stats.worker_busy > Duration::ZERO, "workers must have done the hook work");
    }

    #[test]
    fn mid_iteration_registration_fails_loudly() {
        use crate::hooks::analytics::DegreeStatsHook;
        let data = gen::by_name("wiki", 0.05, 1).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("val").unwrap();
        let mut l = PrefetchLoader::new(
            data.full(),
            BatchBy::Events(100),
            &mut m,
            PrefetchConfig::default().with_workers(2),
        )
        .unwrap();
        assert!(l.next().unwrap().is_ok());
        // Registering under the active key invalidates the snapshot the
        // workers are running; the loader must error, not silently skip
        // the new hook.
        l.manager.register_stateless("val", std::sync::Arc::new(DegreeStatsHook));
        let err = l.next().unwrap().unwrap_err().to_string();
        assert!(err.contains("prefetch iteration"), "{err}");
        // The stream terminates (no sticky-error spin for tolerant consumers).
        assert!(l.next().is_none());
    }

    #[test]
    fn dropping_early_shuts_down_the_pool() {
        let data = gen::by_name("wiki", 0.05, 1).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("val").unwrap();
        let mut l = PrefetchLoader::new(
            data.full(),
            BatchBy::Events(50),
            &mut m,
            // Tiny queue so workers are blocked on send when we bail.
            PrefetchConfig::default().with_workers(2).with_queue_depth(1),
        )
        .unwrap();
        let first = l.next().unwrap().unwrap();
        assert!(first.num_edges() > 0);
        drop(l); // must join cleanly without deadlock
    }
}
