//! Parallel, double-buffered batch materialization.
//!
//! [`PrefetchLoader`] executes the same [`super::BatchPlan`] the serial
//! [`super::DGDataLoader`] would, but pipelines it over worker threads.
//! Since the serving-pool extraction it is a thin façade: it owns a
//! dedicated single-stream [`super::ServingPool`] and drives one
//! [`super::PooledStream`] over it, so the exclusive-loader API keeps
//! working unchanged while multi-tenant callers share one pool across
//! many streams (see [`crate::serving`]):
//!
//! * the pool's **worker threads** materialize planned batches
//!   ([`super::materialize_window`]) and apply the *stateless* hook
//!   phase ([`crate::hooks::StatelessPipeline`]); the stream's bounded
//!   in-flight window gives backpressure, keeping memory proportional
//!   to the queue depth, not the epoch;
//! * the consumer reorders arrivals back into plan order (workers may
//!   finish out of order) and applies the *stateful* hook phase via
//!   [`crate::hooks::HookManager::run_stateful_indexed`], so hooks like
//!   the recency sampler still observe batches strictly in order.
//!
//! **Determinism guarantee.** For any worker count, the yielded batches
//! are byte-identical to the serial loader's: batch boundaries come from
//! the shared plan, stateless hooks draw per-batch RNG streams seeded by
//! the plan index (not a shared generator), and the stateful phase runs
//! in plan order on one thread. The `ablation.prefetch` bench tracks the
//! wall-clock win; the tests in this module pin the equality.

use crate::error::Result;
use crate::graph::DGraph;
use crate::hooks::batch::MaterializedBatch;
use crate::hooks::manager::HookManager;
use crate::loader::{BatchBy, PooledStream, QueueDepth, ServingPool, StreamConfig};
use std::time::Duration;

/// Prefetch pipeline configuration.
#[derive(Debug, Clone)]
pub struct PrefetchConfig {
    /// Worker threads materializing batches. `0` degrades to a serial
    /// in-place pipeline (no threads, same output).
    pub workers: usize,
    /// Bounded in-flight window: how many finished batches may wait
    /// ahead of the consumer. Adaptive by default — sized from the
    /// stream's own consumer-blocked vs worker-busy accounting (see
    /// [`QueueDepth`]); [`PrefetchConfig::with_queue_depth`] is the
    /// fixed escape hatch.
    pub queue_depth: QueueDepth,
    /// Skip empty time buckets (mirrors the serial loader's default).
    pub skip_empty: bool,
    /// Max events per time-iteration batch (see
    /// [`super::DGDataLoader::with_event_cap`]).
    pub event_cap: usize,
}

impl Default for PrefetchConfig {
    fn default() -> Self {
        PrefetchConfig {
            workers: 2,
            queue_depth: QueueDepth::default(),
            skip_empty: true,
            event_cap: usize::MAX,
        }
    }
}

impl PrefetchConfig {
    /// Set the worker count.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Fix the queue depth (disables the adaptive tuner).
    pub fn with_queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = QueueDepth::Fixed(depth.max(1));
        self
    }

    /// Set the full window-sizing policy.
    pub fn with_queue(mut self, depth: QueueDepth) -> Self {
        self.queue_depth = depth;
        self
    }

    /// Keep empty time buckets.
    pub fn with_empty_batches(mut self) -> Self {
        self.skip_empty = false;
        self
    }

    /// Split oversized time buckets to at most `cap` events.
    pub fn with_event_cap(mut self, cap: usize) -> Self {
        self.event_cap = cap.max(1);
        self
    }

    /// The per-stream slice of this config (everything but the worker
    /// count, which belongs to the pool). The window is widened to the
    /// worker count so a dedicated pool never idles for queue space.
    pub fn stream_config(&self) -> StreamConfig {
        StreamConfig {
            queue_depth: self.queue_depth.widened_to(self.workers.max(1)),
            skip_empty: self.skip_empty,
            event_cap: self.event_cap,
            ..StreamConfig::default()
        }
    }
}

/// Wall-clock accounting for the overlap report (Table 11 extension).
#[derive(Debug, Clone, Default)]
pub struct PrefetchStats {
    /// Total planned batches.
    pub batches: usize,
    /// Worker threads used (0 = serial fallback).
    pub workers: usize,
    /// Sum of worker time spent materializing + running stateless hooks.
    /// With overlap, most of this hides behind consumer compute.
    pub worker_busy: Duration,
    /// Time the consumer actually waited on the channel — the part of
    /// the materialization cost that leaked into the critical path.
    pub consumer_blocked: Duration,
    /// In-flight window size at read time (adaptive streams tune this
    /// between [`QueueDepth::Adaptive`] bounds while they run).
    pub queue_depth: usize,
    /// Batches successfully materialized so far (worker or serial side).
    pub mat_batches: u64,
    /// Total [`MaterializedBatch::byte_size`] of those batches.
    pub mat_bytes: u64,
    /// [`crate::kernels::cycles`] ticks spent materializing them (rdtsc
    /// on x86_64, monotonic nanoseconds elsewhere). Feeds the
    /// profiler's cycles/byte row via
    /// [`crate::coordinator::Profiler::add_materialization`].
    pub mat_cycles: u64,
}

/// Loader that materializes batches on a dedicated worker pool and
/// yields them in plan order with the stateful hook phase applied.
pub struct PrefetchLoader<'a> {
    /// Declared before the pool so the stream's cancellation flag is set
    /// before the pool joins its workers.
    stream: PooledStream<'a>,
    _pool: ServingPool,
}

impl<'a> PrefetchLoader<'a> {
    /// Plan the iteration, snapshot the active recipe's stateless phase,
    /// and launch a dedicated worker pool. The manager must be activated
    /// first (same contract as [`super::DGDataLoader`] +
    /// `HookManager::run`).
    pub fn new(
        view: DGraph,
        by: BatchBy,
        manager: &'a mut HookManager,
        cfg: PrefetchConfig,
    ) -> Result<PrefetchLoader<'a>> {
        let pool = ServingPool::new(cfg.workers);
        let stream = pool.stream(view, by, manager, cfg.stream_config())?;
        Ok(PrefetchLoader { stream, _pool: pool })
    }

    /// Exact number of batches remaining.
    pub fn num_batches_hint(&self) -> usize {
        self.stream.num_batches_hint()
    }

    /// The borrowed hook manager (stateful phase owner).
    pub fn manager_mut(&mut self) -> &mut HookManager {
        self.stream.manager_mut()
    }

    /// Overlap accounting so far (read after draining for epoch totals).
    pub fn stats(&self) -> PrefetchStats {
        self.stream.stats()
    }

    /// Next batch in plan order, or `None` when exhausted.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Option<Result<MaterializedBatch>> {
        self.stream.next()
    }

    /// Drain all remaining batches.
    pub fn collect_all(&mut self) -> Result<Vec<MaterializedBatch>> {
        self.stream.collect_all()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hooks::batch::assert_batches_identical;
    use crate::hooks::recipes::{RecipeConfig, RecipeRegistry, SamplerKind, RECIPE_TGB_LINK};
    use crate::io::gen;
    use crate::loader::DGDataLoader;
    use crate::util::TimeGranularity;

    fn serial_batches(key: &str, by: BatchBy, cap: usize) -> Vec<MaterializedBatch> {
        let data = gen::by_name("wiki", 0.05, 1).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate(key).unwrap();
        let mut l = DGDataLoader::new(data.full(), by, &mut m).unwrap().with_event_cap(cap);
        l.collect_all().unwrap()
    }

    fn prefetch_batches(key: &str, by: BatchBy, cap: usize, workers: usize) -> Vec<MaterializedBatch> {
        let data = gen::by_name("wiki", 0.05, 1).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate(key).unwrap();
        let cfg = PrefetchConfig::default().with_workers(workers).with_event_cap(cap);
        let mut l = PrefetchLoader::new(data.full(), by, &mut m, cfg).unwrap();
        l.collect_all().unwrap()
    }

    #[test]
    fn prefetch_matches_serial_for_event_batches() {
        // "train" exercises the mixed pipeline: stateless negatives on
        // workers + the stateful recency sampler on the consumer.
        // "val" exercises an all-stateless pipeline.
        let by = BatchBy::Events(100);
        for key in ["train", "val"] {
            let serial = serial_batches(key, by, usize::MAX);
            assert!(serial.len() >= 4, "want a multi-batch run, got {}", serial.len());
            for workers in [2, 4] {
                let pre = prefetch_batches(key, by, usize::MAX, workers);
                assert_batches_identical(&serial, &pre);
            }
        }
    }

    #[test]
    fn prefetch_matches_serial_for_time_batches() {
        let by = BatchBy::Time(TimeGranularity::Day);
        for key in ["train", "val"] {
            let serial = serial_batches(key, by, 150);
            assert!(serial.len() >= 4, "want a multi-batch run, got {}", serial.len());
            let pre = prefetch_batches(key, by, 150, 3);
            assert_batches_identical(&serial, &pre);
        }
    }

    #[test]
    fn prefetch_matches_serial_with_uniform_sampler() {
        // The uniform sampler is RNG-heavy and stateless: per-batch
        // seeding must reproduce the serial draw order exactly.
        let data = gen::by_name("wiki", 0.05, 2).unwrap();
        let cfg = RecipeConfig { sampler: SamplerKind::Uniform, ..Default::default() };
        let mut m1 = RecipeRegistry::build_with(RECIPE_TGB_LINK, &cfg).unwrap();
        m1.activate("train").unwrap();
        let mut l1 = DGDataLoader::new(data.full(), BatchBy::Events(64), &mut m1).unwrap();
        let serial = l1.collect_all().unwrap();

        let mut m2 = RecipeRegistry::build_with(RECIPE_TGB_LINK, &cfg).unwrap();
        m2.activate("train").unwrap();
        let mut l2 = PrefetchLoader::new(
            data.full(),
            BatchBy::Events(64),
            &mut m2,
            PrefetchConfig::default().with_workers(4).with_queue_depth(2),
        )
        .unwrap();
        let pre = l2.collect_all().unwrap();
        assert_batches_identical(&serial, &pre);
    }

    #[test]
    fn zero_workers_is_a_serial_pipeline() {
        let serial = serial_batches("val", BatchBy::Events(100), usize::MAX);
        let pre = prefetch_batches("val", BatchBy::Events(100), usize::MAX, 0);
        assert_batches_identical(&serial, &pre);
    }

    #[test]
    fn stats_account_worker_time() {
        let data = gen::by_name("wiki", 0.05, 1).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("val").unwrap();
        let mut l = PrefetchLoader::new(
            data.full(),
            BatchBy::Events(100),
            &mut m,
            PrefetchConfig::default().with_workers(2),
        )
        .unwrap();
        let n = l.num_batches_hint();
        let batches = l.collect_all().unwrap();
        assert_eq!(batches.len(), n);
        let stats = l.stats();
        assert_eq!(stats.batches, n);
        assert_eq!(stats.workers, 2);
        assert!(stats.worker_busy > Duration::ZERO, "workers must have done the hook work");
    }

    #[test]
    fn mid_iteration_registration_fails_loudly() {
        use crate::hooks::analytics::DegreeStatsHook;
        let data = gen::by_name("wiki", 0.05, 1).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("val").unwrap();
        let mut l = PrefetchLoader::new(
            data.full(),
            BatchBy::Events(100),
            &mut m,
            PrefetchConfig::default().with_workers(2),
        )
        .unwrap();
        assert!(l.next().unwrap().is_ok());
        // Registering under the active key invalidates the snapshot the
        // workers are running; the loader must error, not silently skip
        // the new hook.
        l.manager_mut().register_stateless("val", std::sync::Arc::new(DegreeStatsHook));
        let err = l.next().unwrap().unwrap_err().to_string();
        assert!(err.contains("prefetch iteration"), "{err}");
        // The stream terminates (no sticky-error spin for tolerant consumers).
        assert!(l.next().is_none());
    }

    #[test]
    fn dropping_early_shuts_down_the_pool() {
        let data = gen::by_name("wiki", 0.05, 1).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("val").unwrap();
        let mut l = PrefetchLoader::new(
            data.full(),
            BatchBy::Events(50),
            &mut m,
            // Tiny queue so the in-flight window is as tight as it gets.
            PrefetchConfig::default().with_workers(2).with_queue_depth(1),
        )
        .unwrap();
        let first = l.next().unwrap().unwrap();
        assert!(first.num_edges() > 0);
        drop(l); // must join cleanly without deadlock
    }
}
