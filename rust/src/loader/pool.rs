//! Shared request-serving worker pool (multi-tenant serving).
//!
//! [`ServingPool`] owns the worker threads that used to live inside
//! [`super::PrefetchLoader`]. Lifting them out lets **many concurrent
//! requests** — batch iterations and point queries, typically one
//! tenant each under a [`crate::serving::TenantRouter`] — multiplex
//! over one fixed set of threads instead of spawning a pool per loader:
//!
//! * every batch iteration is a [`PooledStream`]: it plans its batches
//!   up front, snapshots its manager's stateless phase, and submits
//!   materialization jobs into the pool's scheduler under its tenant's
//!   [`QosTag`];
//! * every point query is a [`crate::graph::PointQuery`] executed
//!   against a [`crate::graph::PointReader`] (a pinned snapshot + CSR
//!   indices) — no batch arena, no hook pipeline — submitted via
//!   [`ServingPool::submit_point`] / [`ServingPool::point_query`];
//! * service order across tenants is a pluggable
//!   [`Scheduler`](crate::loader::sched::Scheduler) —
//!   weighted deficit round robin by default — so one tenant's scan
//!   backlog cannot starve another tenant's point queries, and
//!   per-tenant admission caps shed overload as typed
//!   [`TgmError::Backpressure`] (see [`super::sched`]);
//! * each stream keeps at most `queue_depth` jobs in flight (a sliding
//!   window over its plan), workers send results back over the
//!   submitting stream's private bounded channel, and the consumer side
//!   reorders arrivals into plan order and applies its own *stateful*
//!   hook phase — per-tenant stateful hooks still observe batches
//!   strictly in order even though tenants share workers.
//!
//! **Determinism guarantee.** Exactly the [`super::PrefetchLoader`]
//! guarantee, per stream: batch boundaries come from the plan computed
//! at stream creation, stateless hooks draw per-batch RNG streams
//! seeded by the plan index, and the stateful phase runs in plan order
//! on the consuming thread. Scheduling (FIFO vs DRR, any weights) only
//! changes *service order across requests*, never any request's bytes.
//!
//! Dropping a stream cancels its not-yet-executed jobs (workers skip
//! them via a shared flag). Dropping the pool marks the scheduler shut
//! down **under the same lock submissions take** — a submission
//! therefore either lands before the shutdown (and executes with the
//! backlog) or fails with a typed error; it can never be enqueued where
//! no worker will reach it. Streams and tickets that outlive their pool
//! do not hang: delivered results drain, further waits surface a typed
//! error within one liveness poll.

use crate::error::{Result, TgmError};
use crate::graph::{DGraph, PointQuery, PointReader, PointResponse, StorageSnapshot};
use crate::hooks::batch::MaterializedBatch;
use crate::hooks::manager::{HookManager, StatelessPipeline};
use crate::kernels;
use crate::loader::sched::{
    LatencyHistogram, QosTag, RequestClass, SchedEntry, Scheduler, SchedulerKind, BATCH_COST,
    POINT_COST,
};
use crate::loader::{affinity, materialize_window, plan_batches, BatchBy, BatchPlan};
use crate::obs::{self, Counter, Gauge, Histogram, Label};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// One worker-to-consumer message: plan position plus the materialized
/// batch (or the error that produced it).
type WorkerMsg = (usize, Result<MaterializedBatch>);

/// Per-stream materialization raw-speed counters: `(batches, bytes,
/// cycles)` — batch arenas built, their [`MaterializedBatch::byte_size`]
/// total, and [`kernels::cycles`] ticks spent building them. Shared with
/// workers the same way `busy` is; surfaced via
/// [`super::PrefetchStats`] and the profiler's materialization row.
type MatCounters = Arc<Mutex<(u64, u64, u64)>>;

/// How long a blocked consumer waits between pool-liveness checks. Only
/// paid when the pool died under a stream (or a worker is genuinely this
/// slow); the normal path never sees the timeout.
const POOL_LIVENESS_POLL: Duration = Duration::from_millis(50);

/// Adaptive streams reconsider their window every this many consumed
/// batches.
const ADAPT_EVERY: usize = 8;

/// Consumer-blocked time below this (per tuning window) counts as "the
/// queue always had a batch ready" — scheduler noise, not starvation.
const ADAPT_BLOCK_EPSILON: Duration = Duration::from_micros(200);

/// One batch-materialization unit of pool work: materialize one planned
/// batch of one stream and run that stream's stateless hook phase.
struct Job {
    storage: Arc<StorageSnapshot>,
    plan: BatchPlan,
    pipeline: StatelessPipeline,
    /// Plan position; echoed back so the consumer can reorder.
    seq: usize,
    /// Set when the submitting stream is dropped: skip without running.
    cancelled: Arc<AtomicBool>,
    /// Per-stream worker-busy accounting (for [`super::PrefetchStats`]).
    busy: Arc<Mutex<Duration>>,
    /// Per-stream materialization byte/cycle counters.
    mat: MatCounters,
    /// The submitting stream's private result channel.
    reply: SyncSender<WorkerMsg>,
}

/// One point-query unit of pool work: execute against the pinned
/// reader, no batch, no hooks.
struct PointJob {
    reader: PointReader,
    query: PointQuery,
    reply: SyncSender<Result<PointResponse>>,
}

/// The pool's unified request payload, scheduled by class and tenant.
enum Work {
    Batch(Box<Job>),
    Point(Box<PointJob>),
}

/// Scheduler state plus the shutdown flag, under ONE mutex so
/// submit-vs-shutdown is atomic: a request is either admitted before
/// the shutdown (workers drain it) or rejected with a typed error.
struct QueueInner {
    sched: Box<dyn Scheduler<Work>>,
    shutdown: bool,
}

/// The pool's request queue: scheduler + condvar workers park on.
struct JobQueue {
    inner: Mutex<QueueInner>,
    ready: Condvar,
    /// Live scheduler depth (`tgm_pool_queue_depth{pool}`), mirrored to
    /// the registry on every enqueue/dequeue under the queue lock.
    depth: Gauge,
}

impl JobQueue {
    /// Admit one request (atomically with the shutdown check) and wake
    /// a worker.
    fn submit(&self, tag: &QosTag, cost: u32, payload: Work) -> Result<()> {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if inner.shutdown {
            return Err(TgmError::Hook(
                "serving pool shut down while a request was being submitted".into(),
            ));
        }
        inner.sched.enqueue(SchedEntry {
            tag: tag.clone(),
            cost,
            enqueued: Instant::now(),
            payload,
        })?;
        self.depth.set(inner.sched.len() as i64);
        drop(inner);
        self.ready.notify_one();
        Ok(())
    }
}

/// Per-pool QoS accounting as a view over the global metrics registry:
/// per-class latency histograms (`tgm_point_latency_us{pool}`,
/// `tgm_scan_latency_us{pool}`) and per-`(tenant, class)` completion
/// counters (`tgm_requests_completed_total{pool,tenant,class}`). The
/// unique `pool` label keeps [`ServingPool::qos_stats`] exact per pool
/// while the same series are scrapeable through `/metrics`.
struct QosShared {
    pool: Label,
    point: Histogram,
    scan: Histogram,
    /// Counter-handle cache; the mutex is held only for the map lookup
    /// (the first completion of a `(tenant, class)` registers its
    /// series), the increment itself is lock-free.
    completed: Mutex<HashMap<(Arc<str>, RequestClass), Counter>>,
}

impl QosShared {
    fn new() -> Arc<QosShared> {
        static POOL_SEQ: AtomicU64 = AtomicU64::new(0);
        let pool = Label::from(POOL_SEQ.fetch_add(1, Ordering::Relaxed).to_string());
        let registry = obs::registry();
        Arc::new(QosShared {
            point: registry.histogram("tgm_point_latency_us", &[("pool", pool.clone())]),
            scan: registry.histogram("tgm_scan_latency_us", &[("pool", pool.clone())]),
            completed: Mutex::new(HashMap::new()),
            pool,
        })
    }

    fn completion_counter(&self, tenant: &Arc<str>, class: RequestClass) -> Counter {
        let mut g = self.completed.lock().unwrap_or_else(|e| e.into_inner());
        g.entry((Arc::clone(tenant), class))
            .or_insert_with(|| {
                obs::registry().counter(
                    "tgm_requests_completed_total",
                    &[
                        ("pool", self.pool.clone()),
                        ("tenant", Label::from(tenant)),
                        ("class", Label::from(class.label())),
                    ],
                )
            })
            .clone()
    }
}

fn record_completion(qos: &Arc<QosShared>, tag: &QosTag, enqueued: Instant) {
    let us = enqueued.elapsed().as_micros().min(u64::MAX as u128) as u64;
    match tag.class {
        RequestClass::PointQuery => qos.point.record_us(us),
        RequestClass::BatchScan => qos.scan.record_us(us),
    }
    qos.completion_counter(&tag.tenant, tag.class).inc();
}

/// Snapshot of the pool's per-class QoS counters: enqueue-to-completion
/// latency histograms plus per-`(tenant, class)` completed-request
/// counts. Feed the histograms to
/// [`crate::coordinator::Profiler::add_request_latency`] for the
/// per-class p50/p99 report rows.
#[derive(Debug, Clone, Default)]
pub struct QosStats {
    /// Point-query latency (enqueue to completion), microseconds.
    pub point: LatencyHistogram,
    /// Batch-job latency (enqueue to completion), microseconds.
    pub scan: LatencyHistogram,
    completed: HashMap<(Arc<str>, RequestClass), u64>,
}

impl QosStats {
    /// Requests of `class` completed for `tenant`.
    pub fn completed(&self, tenant: &str, class: RequestClass) -> u64 {
        self.completed
            .iter()
            .filter(|((t, c), _)| t.as_ref() == tenant && *c == class)
            .map(|(_, n)| *n)
            .sum()
    }

    /// Requests of `class` completed across all tenants.
    pub fn total_completed(&self, class: RequestClass) -> u64 {
        self.completed.iter().filter(|((_, c), _)| *c == class).map(|(_, n)| *n).sum()
    }

    /// The latency histogram of `class`.
    pub fn class(&self, class: RequestClass) -> &LatencyHistogram {
        match class {
            RequestClass::PointQuery => &self.point,
            RequestClass::BatchScan => &self.scan,
        }
    }
}

/// Completed point-query ticket: wait for the response without holding
/// the pool borrow (lets callers pipeline many queries).
pub struct PointTicket {
    rx: Receiver<Result<PointResponse>>,
    pool_closed: Arc<AtomicBool>,
}

impl PointTicket {
    /// Block until the response arrives. Fails fast (bounded by one
    /// liveness poll) if the pool died under the query.
    pub fn wait(self) -> Result<PointResponse> {
        loop {
            match self.rx.recv_timeout(POOL_LIVENESS_POLL) {
                Ok(res) => return res,
                Err(RecvTimeoutError::Timeout) => {
                    if self.pool_closed.load(Ordering::SeqCst) {
                        // Flag first, then one final drain attempt:
                        // results landed before shutdown are still valid.
                        if let Ok(res) = self.rx.try_recv() {
                            return res;
                        }
                        return Err(TgmError::Serving(
                            "serving pool shut down while a point query was in flight".into(),
                        ));
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(TgmError::Serving(
                        "point-query reply channel disconnected unexpectedly".into(),
                    ));
                }
            }
        }
    }
}

/// How a stream sizes its in-flight window (how many of its jobs may be
/// queued or finished-but-unconsumed at once).
///
/// The window only changes *scheduling* — how far ahead of the consumer
/// the workers may run — never the output: batches always arrive in
/// plan order with per-plan-index RNG seeds, so serial/pooled
/// determinism holds for any (even varying) depth.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueDepth {
    /// A fixed window (the escape hatch; the pre-adaptive behavior).
    Fixed(usize),
    /// Self-tuning window in `[min, max]`: starts at `min`, widens while
    /// the consumer is observed blocking on the pool (the same
    /// consumer-blocked vs worker-busy accounting the profiler reports)
    /// and narrows back while batches are always ready, bounding
    /// prefetched-batch memory to what the consumer actually needs.
    Adaptive {
        /// Smallest (and initial) window.
        min: usize,
        /// Largest window the tuner may grow to.
        max: usize,
    },
}

impl Default for QueueDepth {
    fn default() -> Self {
        QueueDepth::Adaptive { min: 2, max: 32 }
    }
}

impl QueueDepth {
    /// Smallest (and initial) window size.
    pub(crate) fn floor(self) -> usize {
        match self {
            QueueDepth::Fixed(d) => d.max(1),
            QueueDepth::Adaptive { min, .. } => min.max(1),
        }
    }

    /// Largest window size (reply channels are provisioned for this).
    pub(crate) fn cap(self) -> usize {
        match self {
            QueueDepth::Fixed(d) => d.max(1),
            QueueDepth::Adaptive { min, max } => max.max(min).max(1),
        }
    }

    pub(crate) fn is_adaptive(self) -> bool {
        matches!(self, QueueDepth::Adaptive { .. })
    }

    /// Raise both bounds to at least `n` (a dedicated pool should never
    /// idle for queue space).
    pub(crate) fn widened_to(self, n: usize) -> QueueDepth {
        match self {
            QueueDepth::Fixed(d) => QueueDepth::Fixed(d.max(n)),
            QueueDepth::Adaptive { min, max } => {
                QueueDepth::Adaptive { min: min.max(n), max: max.max(n) }
            }
        }
    }
}

/// Per-stream configuration (the pool itself only fixes the worker
/// count and scheduler; everything batch-shaped is chosen per
/// iteration).
#[derive(Debug, Clone)]
pub struct StreamConfig {
    /// Sliding-window sizing; adaptive by default (see [`QueueDepth`]).
    pub queue_depth: QueueDepth,
    /// Skip empty time buckets (mirrors the serial loader's default).
    pub skip_empty: bool,
    /// Max events per time-iteration batch (see
    /// [`super::DGDataLoader::with_event_cap`]).
    pub event_cap: usize,
    /// Scheduling identity of the stream's batch jobs; the anonymous
    /// shared tag (weight 1, uncapped) by default.
    /// [`crate::serving::TenantRouter::serve`] stamps the tenant's tag.
    pub qos: QosTag,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            queue_depth: QueueDepth::default(),
            skip_empty: true,
            event_cap: usize::MAX,
            qos: QosTag::shared_batch(),
        }
    }
}

impl StreamConfig {
    /// Fix the in-flight window size (disables the adaptive tuner).
    pub fn with_queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = QueueDepth::Fixed(depth.max(1));
        self
    }

    /// Self-tune the in-flight window within `[min, max]`.
    pub fn with_adaptive_depth(mut self, min: usize, max: usize) -> Self {
        self.queue_depth = QueueDepth::Adaptive { min: min.max(1), max: max.max(min).max(1) };
        self
    }

    /// Keep empty time buckets.
    pub fn with_empty_batches(mut self) -> Self {
        self.skip_empty = false;
        self
    }

    /// Split oversized time buckets to at most `cap` events.
    pub fn with_event_cap(mut self, cap: usize) -> Self {
        self.event_cap = cap.max(1);
        self
    }

    /// Submit this stream's jobs under `tag` (tenant weight + admission
    /// cap; the class is forced to [`RequestClass::BatchScan`]).
    pub fn with_qos(mut self, tag: QosTag) -> Self {
        self.qos = QosTag { class: RequestClass::BatchScan, ..tag };
        self
    }
}

/// A fixed set of worker threads multiplexing batch-materialization
/// jobs and point queries from any number of concurrent submitters.
///
/// The pool may be dropped while streams or tickets are still alive:
/// workers finish the already-queued backlog, and survivors surface a
/// typed error (never a hang) on their next submission or wait.
pub struct ServingPool {
    /// Request queue. `None` for a 0-worker pool (streams run their
    /// serial fallback; point queries execute inline on the caller).
    queue: Option<Arc<JobQueue>>,
    /// Raised by `drop` before workers are joined; streams poll it so a
    /// wait on a dead pool fails fast instead of blocking forever.
    closed: Arc<AtomicBool>,
    /// Per-class latency + per-tenant completion counters (registry
    /// view; see [`QosShared`]).
    qos: Arc<QosShared>,
    handles: Vec<thread::JoinHandle<()>>,
    workers: usize,
}

impl ServingPool {
    /// Spawn `workers` threads. `0` creates an inert pool whose streams
    /// all run the serial in-place fallback (no threads, same output).
    /// Workers are CPU-pinned when the `TGM_PIN_WORKERS` env var asks
    /// for it (see [`affinity`]); the scheduler comes from `TGM_QOS`
    /// (weighted DRR unless `TGM_QOS=fifo`).
    pub fn new(workers: usize) -> ServingPool {
        ServingPool::with_affinity(workers, affinity::env_pin_plan().unwrap_or_default())
    }

    /// Spawn `workers` threads, pinning worker `i` to `cpus[i % len]`
    /// when `cpus` is non-empty. Pinning failures (CPU offline, cpuset
    /// restrictions, non-Linux platform) are silently ignored — the
    /// worker just runs unpinned; output is identical either way.
    pub fn with_affinity(workers: usize, cpus: Vec<usize>) -> ServingPool {
        ServingPool::build(workers, cpus, SchedulerKind::from_env())
    }

    /// Spawn `workers` threads with an explicit scheduler policy
    /// (ignoring `TGM_QOS`).
    pub fn with_scheduler(workers: usize, kind: SchedulerKind) -> ServingPool {
        ServingPool::build(workers, affinity::env_pin_plan().unwrap_or_default(), kind)
    }

    fn build(workers: usize, cpus: Vec<usize>, kind: SchedulerKind) -> ServingPool {
        let closed = Arc::new(AtomicBool::new(false));
        let qos = QosShared::new();
        if workers == 0 {
            return ServingPool { queue: None, closed, qos, handles: Vec::new(), workers: 0 };
        }
        let queue = Arc::new(JobQueue {
            inner: Mutex::new(QueueInner { sched: kind.build(), shutdown: false }),
            ready: Condvar::new(),
            depth: obs::registry().gauge("tgm_pool_queue_depth", &[("pool", qos.pool.clone())]),
        });
        let handles = (0..workers)
            .map(|w| {
                let queue = Arc::clone(&queue);
                let qos = Arc::clone(&qos);
                let pin = if cpus.is_empty() { None } else { Some(cpus[w % cpus.len()]) };
                thread::spawn(move || {
                    if let Some(cpu) = pin {
                        let _ = affinity::pin_current_thread(cpu);
                    }
                    loop {
                        // Hold the lock only while dequeueing; execution
                        // runs unlocked so workers overlap. Workers only
                        // exit once the scheduler is BOTH shut down and
                        // drained, so the admitted backlog always runs.
                        let entry = {
                            let mut inner =
                                queue.inner.lock().unwrap_or_else(|e| e.into_inner());
                            loop {
                                if let Some(e) = inner.sched.dequeue() {
                                    queue.depth.set(inner.sched.len() as i64);
                                    break Some(e);
                                }
                                if inner.shutdown {
                                    break None;
                                }
                                inner = queue
                                    .ready
                                    .wait(inner)
                                    .unwrap_or_else(|e| e.into_inner());
                            }
                        };
                        let Some(entry) = entry else { break };
                        let (tag, enqueued) = (entry.tag, entry.enqueued);
                        match entry.payload {
                            Work::Batch(job) => {
                                if job.cancelled.load(Ordering::Relaxed) {
                                    continue;
                                }
                                run_batch_job(&job);
                                record_completion(&qos, &tag, enqueued);
                            }
                            Work::Point(pj) => {
                                // No hooks run here, but the same
                                // panic fence as the batch path: a
                                // worker must never strand a waiter.
                                let span = obs::span("serving", "point_query")
                                    .with_tenant(&tag.tenant);
                                let res = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(|| pj.reader.execute(&pj.query)),
                                )
                                .map_err(|_| {
                                    TgmError::Serving(
                                        "a point query panicked while executing".into(),
                                    )
                                });
                                drop(span);
                                let _ = pj.reply.send(res);
                                record_completion(&qos, &tag, enqueued);
                            }
                        }
                    }
                })
            })
            .collect();
        ServingPool { queue: Some(queue), closed, qos, handles, workers }
    }

    /// Worker threads owned by the pool.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Snapshot of the per-class QoS counters (latency histograms +
    /// per-tenant completions). This is a view over the global metrics
    /// registry (the same series `/metrics` exposes, filtered to this
    /// pool's unique `pool` label), so it is exact per pool and zero
    /// when the registry has been disabled via
    /// [`crate::obs::MetricsRegistry::set_enabled`].
    pub fn qos_stats(&self) -> QosStats {
        let completed = {
            let g = self.qos.completed.lock().unwrap_or_else(|e| e.into_inner());
            g.iter().map(|(k, c)| (k.clone(), c.get())).collect()
        };
        QosStats {
            point: self.qos.point.snapshot(),
            scan: self.qos.scan.snapshot(),
            completed,
        }
    }

    /// Submit one point query under `tag` (class forced to
    /// [`RequestClass::PointQuery`]) and return a ticket to wait on.
    /// Admission control applies: a full tenant point queue rejects
    /// with [`TgmError::Backpressure`]. On a 0-worker pool the query
    /// executes inline on the caller.
    pub fn submit_point(
        &self,
        reader: &PointReader,
        tag: &QosTag,
        query: PointQuery,
    ) -> Result<PointTicket> {
        let tag = QosTag { class: RequestClass::PointQuery, ..tag.clone() };
        let (tx, rx) = sync_channel::<Result<PointResponse>>(1);
        match &self.queue {
            None => {
                let t0 = Instant::now();
                let res = {
                    let _span = obs::span("serving", "point_query").with_tenant(&tag.tenant);
                    reader.execute(&query)
                };
                record_completion(&self.qos, &tag, t0);
                let _ = tx.send(Ok(res));
            }
            Some(queue) => {
                let job = PointJob { reader: reader.clone(), query, reply: tx };
                queue.submit(&tag, POINT_COST, Work::Point(Box::new(job)))?;
            }
        }
        Ok(PointTicket { rx, pool_closed: Arc::clone(&self.closed) })
    }

    /// Submit one point query and block for its response.
    pub fn point_query(
        &self,
        reader: &PointReader,
        tag: &QosTag,
        query: PointQuery,
    ) -> Result<PointResponse> {
        self.submit_point(reader, tag, query)?.wait()
    }

    /// Open one pooled iteration over `view`. Plans the batches,
    /// snapshots the active recipe's stateless phase, and submits the
    /// first window of jobs. The manager must be activated first (same
    /// contract as [`super::DGDataLoader`]).
    pub fn stream<'a>(
        &self,
        view: DGraph,
        by: BatchBy,
        manager: &'a mut HookManager,
        cfg: StreamConfig,
    ) -> Result<PooledStream<'a>> {
        let plans = plan_batches(&view, by, cfg.skip_empty, cfg.event_cap)?;
        let pipeline = manager.stateless_pipeline()?;
        let epoch = manager.registration_epoch();
        let storage = Arc::clone(view.storage());
        // Clamped so `cap + 1` and window arithmetic cannot overflow
        // (and a silly depth cannot pre-materialize a whole epoch).
        let depth_floor = cfg.queue_depth.floor().clamp(1, 1 << 20);
        let depth_cap = cfg.queue_depth.cap().clamp(depth_floor, 1 << 20);
        // An empty plan or an inert pool degrades to the serial path.
        let queue = if plans.is_empty() { None } else { self.queue.clone() };
        let workers = if queue.is_some() { self.workers } else { 0 };
        // The window invariant (`submitted <= next_index + depth`, with
        // `next_index` advanced before topping up) allows `depth + 1`
        // unconsumed results at once; sizing the reply channel to hold
        // all of them — at the tuner's CAP, so shrinking the live window
        // can never strand an in-flight result — means a worker NEVER
        // blocks sending a result, so one slow stream cannot stall
        // workers other streams need.
        let (reply_tx, reply_rx) = sync_channel::<WorkerMsg>(depth_cap + 1);
        let mut stream = PooledStream {
            manager,
            storage,
            plans,
            pipeline,
            queue,
            qos: QosTag { class: RequestClass::BatchScan, ..cfg.qos },
            pool_closed: Arc::clone(&self.closed),
            reply_tx,
            reply_rx,
            cancelled: Arc::new(AtomicBool::new(false)),
            busy: Arc::new(Mutex::new(Duration::ZERO)),
            mat: Arc::new(Mutex::new((0, 0, 0))),
            pending: HashMap::new(),
            submitted: 0,
            next_index: 0,
            blocked: Duration::ZERO,
            depth: depth_floor,
            depth_floor,
            depth_cap,
            adaptive: cfg.queue_depth.is_adaptive(),
            consumed_since_tune: 0,
            tuned_at_blocked: Duration::ZERO,
            tuned_at_busy: Duration::ZERO,
            workers,
            epoch,
        };
        stream.submit_window()?;
        Ok(stream)
    }
}

/// Execute one batch job (worker side): materialize, stateless hooks,
/// account busy/materialization, reply. A panicking hook must not
/// strand the consumer waiting for a reply that will never come, so the
/// panic converts into a typed per-batch error.
fn run_batch_job(job: &Job) {
    let t0 = Instant::now();
    let c0 = kernels::cycles();
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        materialize_window(&job.storage, &job.plan).and_then(|mut b| {
            job.pipeline.run(&mut b, &job.storage, job.plan.index)?;
            Ok(b)
        })
    }))
    .unwrap_or_else(|_| {
        Err(TgmError::Hook("a worker hook panicked while materializing this batch".into()))
    });
    let cycles = kernels::cycles().wrapping_sub(c0);
    if let Ok(mut d) = job.busy.lock() {
        *d += t0.elapsed();
    }
    if let Ok(b) = &res {
        if let Ok(mut m) = job.mat.lock() {
            m.0 += 1;
            m.1 += b.byte_size() as u64;
            m.2 += cycles;
        }
    }
    // A closed reply channel means the stream is gone; keep serving
    // the other streams.
    let _ = job.reply.send((job.seq, res));
}

impl Drop for ServingPool {
    fn drop(&mut self) {
        // Flag first so blocked waiters fail fast, then mark the
        // scheduler shut down UNDER ITS LOCK — atomically with respect
        // to submissions, so no request can be admitted after this
        // point — and wake every worker. Workers drain the admitted
        // backlog before exiting.
        self.closed.store(true, Ordering::SeqCst);
        if let Some(queue) = &self.queue {
            queue.inner.lock().unwrap_or_else(|e| e.into_inner()).shutdown = true;
            queue.ready.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One iteration multiplexed over a [`ServingPool`]: yields batches in
/// plan order with the submitting manager's stateful phase applied on
/// the consuming thread.
pub struct PooledStream<'a> {
    manager: &'a mut HookManager,
    storage: Arc<StorageSnapshot>,
    plans: Vec<BatchPlan>,
    /// Stateless worker phase; also the serial fallback pipeline.
    pipeline: StatelessPipeline,
    /// `None` degrades to the serial in-place path.
    queue: Option<Arc<JobQueue>>,
    /// Scheduling identity of this stream's jobs.
    qos: QosTag,
    /// Shared with the producing pool; true once the pool shut down.
    pool_closed: Arc<AtomicBool>,
    reply_tx: SyncSender<WorkerMsg>,
    reply_rx: Receiver<WorkerMsg>,
    cancelled: Arc<AtomicBool>,
    busy: Arc<Mutex<Duration>>,
    /// Materialization raw-speed counters (worker- or serial-side).
    mat: MatCounters,
    /// Reorder buffer for batches that arrived ahead of plan order.
    pending: HashMap<usize, Result<MaterializedBatch>>,
    /// Plan positions submitted to the pool so far.
    submitted: usize,
    next_index: usize,
    blocked: Duration,
    /// Live in-flight window size (tuned when `adaptive`).
    depth: usize,
    depth_floor: usize,
    depth_cap: usize,
    adaptive: bool,
    /// Tuner bookkeeping: batches consumed and the blocked/busy totals
    /// observed at the last retune.
    consumed_since_tune: usize,
    tuned_at_blocked: Duration,
    tuned_at_busy: Duration,
    workers: usize,
    /// Manager registration epoch at stream creation; see
    /// [`PooledStream::next`].
    epoch: u64,
}

impl<'a> PooledStream<'a> {
    /// Top up the sliding window: submit jobs while fewer than `depth`
    /// of this stream's plans are in flight. The shutdown check and the
    /// enqueue are one atomic step inside [`JobQueue::submit`], so a
    /// job can never land in a queue no worker will drain.
    fn submit_window(&mut self) -> Result<()> {
        let Some(queue) = &self.queue else { return Ok(()) };
        while self.submitted < self.plans.len()
            && self.submitted < self.next_index.saturating_add(self.depth)
        {
            let job = Job {
                storage: Arc::clone(&self.storage),
                plan: self.plans[self.submitted].clone(),
                pipeline: self.pipeline.clone(),
                seq: self.submitted,
                cancelled: Arc::clone(&self.cancelled),
                busy: Arc::clone(&self.busy),
                mat: Arc::clone(&self.mat),
                reply: self.reply_tx.clone(),
            };
            queue.submit(&self.qos, BATCH_COST, Work::Batch(Box::new(job)))?;
            self.submitted += 1;
        }
        Ok(())
    }

    /// Exact number of batches remaining.
    pub fn num_batches_hint(&self) -> usize {
        self.plans.len() - self.next_index
    }

    /// The snapshot this stream is pinned to.
    pub fn storage(&self) -> &Arc<StorageSnapshot> {
        &self.storage
    }

    /// The borrowed hook manager (stateful phase owner).
    pub fn manager_mut(&mut self) -> &mut HookManager {
        self.manager
    }

    /// Overlap accounting so far (read after draining for totals).
    pub fn stats(&self) -> super::PrefetchStats {
        let (mat_batches, mat_bytes, mat_cycles) =
            *self.mat.lock().unwrap_or_else(|e| e.into_inner());
        super::PrefetchStats {
            batches: self.plans.len(),
            workers: self.workers,
            worker_busy: *self.busy.lock().unwrap_or_else(|e| e.into_inner()),
            consumer_blocked: self.blocked,
            queue_depth: self.depth,
            mat_batches,
            mat_bytes,
            mat_cycles,
        }
    }

    /// Retune the adaptive window from the same counters the profiler's
    /// overlap report is built on: if the consumer spent a meaningful
    /// share of the last window blocked on the pool (vs what the
    /// workers were busy producing), widen so workers run further
    /// ahead; if every batch was ready on arrival, narrow back toward
    /// the floor to bound prefetched-batch memory. Scheduling only —
    /// batch bytes and order are depth-independent.
    fn maybe_retune(&mut self) {
        if !self.adaptive {
            return;
        }
        self.consumed_since_tune += 1;
        if self.consumed_since_tune < ADAPT_EVERY {
            return;
        }
        self.consumed_since_tune = 0;
        let busy_total = *self.busy.lock().unwrap_or_else(|e| e.into_inner());
        let blocked_delta = self.blocked.saturating_sub(self.tuned_at_blocked);
        let busy_delta = busy_total.saturating_sub(self.tuned_at_busy);
        self.tuned_at_blocked = self.blocked;
        self.tuned_at_busy = busy_total;
        if blocked_delta > ADAPT_BLOCK_EPSILON && blocked_delta * 4 > busy_delta {
            self.depth = (self.depth.saturating_mul(2)).min(self.depth_cap);
        } else if blocked_delta <= ADAPT_BLOCK_EPSILON && self.depth > self.depth_floor {
            self.depth -= 1;
        }
    }

    /// Next batch in plan order, or `None` when exhausted.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Option<Result<MaterializedBatch>> {
        if self.next_index >= self.plans.len() {
            return None;
        }
        // The worker pipeline is a point-in-time snapshot of the recipe;
        // registering hooks mid-iteration would silently diverge from
        // the serial loader, so fail loudly — and terminate the stream,
        // so error-tolerant consumers cannot spin on a sticky error.
        if self.manager.registration_epoch() != self.epoch {
            self.next_index = self.plans.len();
            return Some(Err(TgmError::Hook(
                "hooks were registered while a prefetch iteration was in flight; \
                 recreate the loader to pick them up"
                    .into(),
            )));
        }
        let idx = self.next_index;
        self.next_index += 1;

        // Serial fallback: materialize inline, no pool involved. The
        // materialization counters still accumulate so the profiler's
        // cycles/byte row covers serial and pooled runs alike.
        if self.queue.is_none() {
            let plan = self.plans[idx].clone();
            let c0 = kernels::cycles();
            let mut batch = match materialize_window(&self.storage, &plan) {
                Ok(b) => b,
                Err(e) => return Some(Err(e)),
            };
            if let Err(e) = self.pipeline.run(&mut batch, &self.storage, plan.index) {
                return Some(Err(e));
            }
            let cycles = kernels::cycles().wrapping_sub(c0);
            if let Ok(mut m) = self.mat.lock() {
                m.0 += 1;
                m.1 += batch.byte_size() as u64;
                m.2 += cycles;
            }
            if let Err(e) = self.manager.run_stateful_indexed(&mut batch, &self.storage, plan.index)
            {
                return Some(Err(e));
            }
            return Some(Ok(batch));
        }

        // Advancing the consumer index freed a window slot.
        if let Err(e) = self.submit_window() {
            self.next_index = self.plans.len();
            return Some(Err(e));
        }

        // Pull from the pool, reordering into plan order. The stream
        // holds its own `reply_tx`, so the reply channel cannot
        // disconnect while we wait — pool death is detected via the
        // shared `closed` flag instead (bounded by the liveness poll).
        let t0 = Instant::now();
        let res = loop {
            if let Some(r) = self.pending.remove(&idx) {
                break r;
            }
            match self.reply_rx.recv_timeout(POOL_LIVENESS_POLL) {
                Ok((i, r)) => {
                    if i == idx {
                        break r;
                    }
                    self.pending.insert(i, r);
                }
                Err(RecvTimeoutError::Timeout) => {
                    // Flag first, then one final drain attempt: results
                    // that landed before the shutdown are still valid.
                    if self.pool_closed.load(Ordering::SeqCst) {
                        if let Ok((i, r)) = self.reply_rx.try_recv() {
                            if i == idx {
                                break r;
                            }
                            self.pending.insert(i, r);
                            continue;
                        }
                        break Err(TgmError::Hook(
                            "serving pool shut down while this stream was waiting for a batch"
                                .into(),
                        ));
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    // Unreachable in practice: the stream itself owns a
                    // reply sender, so the channel cannot disconnect
                    // while it waits. Defensive error, not a panic.
                    break Err(TgmError::Hook(
                        "prefetch reply channel disconnected unexpectedly".into(),
                    ));
                }
            }
        };
        self.blocked += t0.elapsed();
        self.maybe_retune();

        match res {
            Ok(mut batch) => {
                let plan_index = self.plans[idx].index;
                if let Err(e) =
                    self.manager.run_stateful_indexed(&mut batch, &self.storage, plan_index)
                {
                    return Some(Err(e));
                }
                Some(Ok(batch))
            }
            Err(e) => Some(Err(e)),
        }
    }

    /// Drain all remaining batches.
    pub fn collect_all(&mut self) -> Result<Vec<MaterializedBatch>> {
        let mut out = Vec::new();
        while let Some(b) = self.next() {
            out.push(b?);
        }
        Ok(out)
    }
}

impl Drop for PooledStream<'_> {
    fn drop(&mut self) {
        // Not-yet-executed jobs of this stream are skipped by workers;
        // already-executing ones fail their reply send harmlessly.
        self.cancelled.store(true, Ordering::Relaxed);
        self.pending.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::AdjacencyCache;
    use crate::hooks::batch::assert_batches_identical as identical;
    use crate::hooks::recipes::{RecipeRegistry, RECIPE_TGB_LINK};
    use crate::io::gen;
    use crate::loader::DGDataLoader;
    use std::collections::VecDeque;

    fn serial(key: &str, seed: u64) -> Vec<MaterializedBatch> {
        let data = gen::by_name("wiki", 0.05, seed).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate(key).unwrap();
        DGDataLoader::new(data.full(), BatchBy::Events(100), &mut m)
            .unwrap()
            .collect_all()
            .unwrap()
    }

    #[test]
    fn two_streams_share_one_pool_deterministically() {
        // Two independent iterations (distinct datasets and stateful
        // managers) interleaved over the same 3-worker pool must each be
        // byte-identical to their serial runs.
        let pool = ServingPool::new(3);
        let d1 = gen::by_name("wiki", 0.05, 1).unwrap();
        let d2 = gen::by_name("wiki", 0.05, 2).unwrap();
        let mut m1 = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        let mut m2 = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m1.activate("train").unwrap();
        m2.activate("train").unwrap();
        let mut s1 = pool
            .stream(d1.full(), BatchBy::Events(100), &mut m1, StreamConfig::default())
            .unwrap();
        let mut s2 = pool
            .stream(d2.full(), BatchBy::Events(100), &mut m2, StreamConfig::default())
            .unwrap();

        // Interleave consumption so both windows stay in flight at once.
        let mut got1 = Vec::new();
        let mut got2 = Vec::new();
        loop {
            let a = s1.next();
            let b = s2.next();
            if let Some(x) = a {
                got1.push(x.unwrap());
            }
            if let Some(y) = b {
                got2.push(y.unwrap());
            }
            if got1.len() + got2.len() >= s1.stats().batches + s2.stats().batches {
                break;
            }
        }
        identical(&serial("train", 1), &got1);
        identical(&serial("train", 2), &got2);
    }

    #[test]
    fn pool_outlives_streams_and_serves_again() {
        let pool = ServingPool::new(2);
        for seed in [1u64, 2, 3] {
            let data = gen::by_name("wiki", 0.05, seed).unwrap();
            let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
            m.activate("val").unwrap();
            let mut s = pool
                .stream(data.full(), BatchBy::Events(100), &mut m, StreamConfig::default())
                .unwrap();
            let got = s.collect_all().unwrap();
            drop(s);
            identical(&serial("val", seed), &got);
        }
    }

    #[test]
    fn dropping_a_stream_mid_iteration_leaves_the_pool_healthy() {
        let pool = ServingPool::new(2);
        let data = gen::by_name("wiki", 0.05, 4).unwrap();
        {
            let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
            m.activate("val").unwrap();
            let mut s = pool
                .stream(
                    data.full(),
                    BatchBy::Events(50),
                    &mut m,
                    StreamConfig::default().with_queue_depth(1),
                )
                .unwrap();
            assert!(s.next().unwrap().is_ok());
            // Dropped with most of the plan unconsumed.
        }
        // The pool still serves a fresh stream to completion.
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("val").unwrap();
        let mut s = pool
            .stream(data.full(), BatchBy::Events(100), &mut m, StreamConfig::default())
            .unwrap();
        let got = s.collect_all().unwrap();
        identical(&serial("val", 4), &got);
    }

    #[test]
    fn pool_drop_with_live_stream_fails_fast_instead_of_hanging() {
        let data = gen::by_name("wiki", 0.05, 6).unwrap();

        // Every plan fits in the window: the backlog is admitted before
        // the pool's shutdown, so the orphaned stream still completes
        // (workers drain the backlog before exiting).
        let mut m1 = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m1.activate("val").unwrap();
        let mut small = {
            let pool = ServingPool::new(2);
            pool.stream(
                data.full(),
                BatchBy::Events(100),
                &mut m1,
                StreamConfig::default().with_queue_depth(64),
            )
            .unwrap()
            // The pool is dropped here, while the stream lives on.
        };
        let got = small.collect_all().unwrap();
        identical(&serial("val", 6), &got);

        // More plans than the window: the stream must surface a typed
        // error promptly, not block forever on the dead pool.
        let mut m2 = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m2.activate("val").unwrap();
        let mut big = {
            let pool = ServingPool::new(2);
            pool.stream(
                data.full(),
                BatchBy::Events(20),
                &mut m2,
                StreamConfig::default().with_queue_depth(2),
            )
            .unwrap()
        };
        let mut saw_error = false;
        while let Some(b) = big.next() {
            if let Err(e) = b {
                assert!(e.to_string().contains("shut down"), "{e}");
                saw_error = true;
                break;
            }
        }
        assert!(saw_error, "a dead pool must surface as an error, not a hang");
    }

    /// Satellite regression: a stream racing a concurrently-dropping
    /// pool must never park a job where no worker will reach it. The
    /// shutdown flag and the enqueue share one lock, so every submission
    /// either executes with the backlog or errors — pin that by racing
    /// drop against consumption many times and requiring every batch to
    /// resolve (value or typed error) promptly.
    #[test]
    fn concurrent_pool_drop_and_submission_resolve_without_hanging() {
        let data = gen::by_name("wiki", 0.05, 11).unwrap();
        for round in 0..20 {
            let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
            m.activate("val").unwrap();
            let pool = ServingPool::new(2);
            let mut s = pool
                .stream(
                    data.full(),
                    BatchBy::Events(25),
                    &mut m,
                    StreamConfig::default().with_queue_depth(2),
                )
                .unwrap();
            let dropper = thread::spawn(move || {
                // Stagger the drop across rounds to cover the window
                // between the closed-flag store and the queue lock.
                if round % 4 != 0 {
                    thread::sleep(Duration::from_micros(50 * round as u64));
                }
                drop(pool);
            });
            let t0 = Instant::now();
            let mut results = 0usize;
            while let Some(b) = s.next() {
                match b {
                    Ok(_) => results += 1,
                    Err(e) => {
                        assert!(e.to_string().contains("shut down"), "{e}");
                        break;
                    }
                }
            }
            assert!(
                t0.elapsed() < Duration::from_secs(20),
                "round {round}: stream took {:?} to resolve ({results} batches)",
                t0.elapsed()
            );
            dropper.join().unwrap();

            // After the drop, further submissions fail fast and typed.
            drop(s);
        }
    }

    #[test]
    fn adaptive_depth_is_bounded_and_byte_identical_to_fixed() {
        let serial = serial("train", 9);
        let pool = ServingPool::new(3);
        let data = gen::by_name("wiki", 0.05, 9).unwrap();

        let mut mf = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        mf.activate("train").unwrap();
        let mut fixed = pool
            .stream(
                data.full(),
                BatchBy::Events(100),
                &mut mf,
                StreamConfig::default().with_queue_depth(4),
            )
            .unwrap();
        let fixed_batches = fixed.collect_all().unwrap();
        assert_eq!(fixed.stats().queue_depth, 4, "fixed depth must not tune");
        identical(&serial, &fixed_batches);

        let mut ma = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        ma.activate("train").unwrap();
        let mut adaptive = pool
            .stream(
                data.full(),
                BatchBy::Events(100),
                &mut ma,
                StreamConfig::default().with_adaptive_depth(1, 64),
            )
            .unwrap();
        let mut got = Vec::new();
        while let Some(b) = adaptive.next() {
            let depth = adaptive.stats().queue_depth;
            assert!((1..=64).contains(&depth), "tuned depth {depth} out of bounds");
            got.push(b.unwrap());
        }
        identical(&serial, &got);
    }

    #[test]
    fn queue_depth_bounds() {
        assert_eq!(QueueDepth::Fixed(0).floor(), 1);
        assert_eq!(QueueDepth::Fixed(7).cap(), 7);
        let a = QueueDepth::Adaptive { min: 3, max: 2 };
        assert_eq!(a.floor(), 3);
        assert_eq!(a.cap(), 3, "an inverted range collapses to the floor");
        assert!(a.is_adaptive());
        assert_eq!(QueueDepth::Fixed(2).widened_to(5), QueueDepth::Fixed(5));
        assert_eq!(
            QueueDepth::Adaptive { min: 2, max: 4 }.widened_to(8),
            QueueDepth::Adaptive { min: 8, max: 8 }
        );
        assert_eq!(QueueDepth::default().floor(), 2);
    }

    #[test]
    fn zero_worker_pool_runs_serially() {
        let pool = ServingPool::new(0);
        assert_eq!(pool.workers(), 0);
        let data = gen::by_name("wiki", 0.05, 5).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("val").unwrap();
        let mut s = pool
            .stream(data.full(), BatchBy::Events(100), &mut m, StreamConfig::default())
            .unwrap();
        assert_eq!(s.stats().workers, 0);
        let got = s.collect_all().unwrap();
        identical(&serial("val", 5), &got);
        // The serial fallback still accounts materialization raw speed.
        let stats = s.stats();
        assert_eq!(stats.mat_batches as usize, got.len());
        let bytes: usize = got.iter().map(|b| b.byte_size()).sum();
        assert_eq!(stats.mat_bytes as usize, bytes);
    }

    #[test]
    fn pooled_streams_account_materialization_bytes() {
        let pool = ServingPool::new(2);
        let data = gen::by_name("wiki", 0.05, 7).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("val").unwrap();
        let mut s = pool
            .stream(data.full(), BatchBy::Events(100), &mut m, StreamConfig::default())
            .unwrap();
        let got = s.collect_all().unwrap();
        let stats = s.stats();
        assert_eq!(stats.mat_batches as usize, got.len());
        // Worker-side byte_size is measured before the consumer's
        // stateful phase adds attributes, so it lower-bounds the final
        // batch sizes and is strictly positive.
        let final_bytes: usize = got.iter().map(|b| b.byte_size()).sum();
        assert!(stats.mat_bytes > 0);
        assert!(stats.mat_bytes as usize <= final_bytes);
    }

    #[test]
    fn explicit_affinity_pool_serves_identically() {
        // Pinning is scheduling-only; even an absurd CPU list (pin
        // failures ignored) must leave output byte-identical.
        let pool = ServingPool::with_affinity(2, vec![0, 1 << 20]);
        let data = gen::by_name("wiki", 0.05, 8).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("train").unwrap();
        let mut s = pool
            .stream(data.full(), BatchBy::Events(100), &mut m, StreamConfig::default())
            .unwrap();
        let got = s.collect_all().unwrap();
        identical(&serial("train", 8), &got);
    }

    #[test]
    fn streams_open_from_other_threads() {
        // The pool is Sync: scoped threads open and drain their own
        // streams concurrently against one shared pool.
        let pool = ServingPool::new(4);
        let results: Vec<Vec<MaterializedBatch>> = thread::scope(|scope| {
            let handles: Vec<_> = (1u64..=3)
                .map(|seed| {
                    let pool = &pool;
                    scope.spawn(move || {
                        let data = gen::by_name("wiki", 0.05, seed).unwrap();
                        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
                        m.activate("train").unwrap();
                        let mut s = pool
                            .stream(
                                data.full(),
                                BatchBy::Events(100),
                                &mut m,
                                StreamConfig::default(),
                            )
                            .unwrap();
                        s.collect_all().unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (seed, got) in (1u64..=3).zip(&results) {
            identical(&serial("train", seed), got);
        }
    }

    fn reader_for(seed: u64) -> PointReader {
        let data = gen::by_name("wiki", 0.05, seed).unwrap();
        PointReader::with_cache(Arc::clone(data.storage()), &AdjacencyCache::new())
    }

    #[test]
    fn point_queries_match_direct_execution_and_share_the_pool() {
        let pool = ServingPool::new(2);
        let reader = reader_for(3);
        let tag = QosTag::new("t", RequestClass::PointQuery, 1);
        let end = reader.snapshot().end_time() + 1;

        // Run a batch stream concurrently so both work classes
        // interleave over the same workers.
        let data = gen::by_name("wiki", 0.05, 3).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("val").unwrap();
        let mut s = pool
            .stream(data.full(), BatchBy::Events(50), &mut m, StreamConfig::default())
            .unwrap();

        for node in 0..32u32 {
            let q = PointQuery::NeighborsBefore { node, t: end, k: 8 };
            let got = pool.point_query(&reader, &tag, q).unwrap();
            assert_eq!(got, reader.execute(&q), "node {node}");
            let _ = s.next();
        }
        let q = PointQuery::EdgeLookup { src: 0, dst: 1, t: end };
        assert_eq!(pool.point_query(&reader, &tag, q).unwrap(), reader.execute(&q));
        let _ = s.collect_all().unwrap();

        let stats = pool.qos_stats();
        assert_eq!(stats.completed("t", RequestClass::PointQuery), 33);
        assert!(stats.total_completed(RequestClass::BatchScan) > 0);
        assert_eq!(stats.point.count(), 33);
        assert!(stats.class(RequestClass::PointQuery).percentile_us(50.0) > 0);
    }

    #[test]
    fn zero_worker_pool_answers_point_queries_inline() {
        let pool = ServingPool::new(0);
        let reader = reader_for(4);
        let tag = QosTag::new("t", RequestClass::PointQuery, 1);
        let end = reader.snapshot().end_time() + 1;
        let q = PointQuery::NeighborsBefore { node: 1, t: end, k: 4 };
        assert_eq!(pool.point_query(&reader, &tag, q).unwrap(), reader.execute(&q));
        assert_eq!(pool.qos_stats().completed("t", RequestClass::PointQuery), 1);
    }

    #[test]
    fn point_ticket_on_dropped_pool_fails_fast() {
        let reader = reader_for(5);
        let tag = QosTag::new("t", RequestClass::PointQuery, 1);
        let end = reader.snapshot().end_time() + 1;
        // Submitted before the drop: the backlog drains, so the ticket
        // resolves with a value.
        let ticket = {
            let pool = ServingPool::new(1);
            pool.submit_point(&reader, &tag, PointQuery::EdgeLookup { src: 0, dst: 1, t: end })
                .unwrap()
            // Pool dropped here.
        };
        assert!(ticket.wait().is_ok(), "admitted backlog must drain on shutdown");
        // Submitting against a dead pool is a typed, fast error — via
        // a stream still holding the queue.
        let data = gen::by_name("wiki", 0.05, 5).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("val").unwrap();
        let mut s = {
            let pool = ServingPool::new(1);
            pool.stream(
                data.full(),
                BatchBy::Events(20),
                &mut m,
                StreamConfig::default().with_queue_depth(1),
            )
            .unwrap()
        };
        let t0 = Instant::now();
        let mut saw_error = false;
        while let Some(b) = s.next() {
            if b.is_err() {
                saw_error = true;
                break;
            }
        }
        assert!(saw_error);
        assert!(t0.elapsed() < Duration::from_secs(10));
    }

    /// ISSUE satellite: under saturating 2-tenant point-query load with
    /// weights (1, 3), completed-request ratios converge within 10% —
    /// at 1, 2 and 4 workers.
    #[test]
    fn weighted_tenants_converge_to_weight_ratio_at_1_2_4_workers() {
        for workers in [1usize, 2, 4] {
            let pool = ServingPool::with_scheduler(workers, SchedulerKind::WeightedDrr);
            let reader = reader_for(6);
            let end = reader.snapshot().end_time() + 1;
            // Busiest node miss-lookup: the scan touches the whole
            // time-cut run, keeping service time meaningfully above
            // submission time so the queue stays saturated.
            let miss = PointQuery::EdgeLookup { src: 0, dst: (1 << 20) as u32, t: end };
            let stop = AtomicBool::new(false);
            let target = 6000u64;

            thread::scope(|scope| {
                for (tenant, weight) in [("light", 1u32), ("heavy", 3u32)] {
                    let pool = &pool;
                    let reader = &reader;
                    let stop = &stop;
                    scope.spawn(move || {
                        let tag = QosTag::new(tenant, RequestClass::PointQuery, weight)
                            .with_max_queued(1 << 20);
                        let mut outstanding = VecDeque::new();
                        while !stop.load(Ordering::Relaxed) {
                            while outstanding.len() < 64 {
                                outstanding
                                    .push_back(pool.submit_point(reader, &tag, miss).unwrap());
                            }
                            outstanding.pop_front().unwrap().wait().unwrap();
                        }
                        for t in outstanding {
                            let _ = t.wait();
                        }
                    });
                }
                // Snapshot the counters the moment the target volume is
                // reached, while both tenants are still saturated.
                let stats = loop {
                    let stats = pool.qos_stats();
                    if stats.total_completed(RequestClass::PointQuery) >= target {
                        break stats;
                    }
                    thread::sleep(Duration::from_millis(1));
                };
                stop.store(true, Ordering::Relaxed);
                let light = stats.completed("light", RequestClass::PointQuery) as f64;
                let heavy = stats.completed("heavy", RequestClass::PointQuery) as f64;
                let ratio = heavy / light.max(1.0);
                assert!(
                    (ratio - 3.0).abs() / 3.0 < 0.10,
                    "workers={workers}: completed ratio {ratio:.3} (heavy {heavy}, light {light})"
                );
            });
        }
    }

    /// ISSUE satellite: a point query is never starved behind another
    /// tenant's batch-scan backlog — worst-case delay is one DRR round,
    /// not the backlog length.
    #[test]
    fn point_queries_are_not_starved_behind_batch_backlog() {
        let pool = ServingPool::with_scheduler(1, SchedulerKind::WeightedDrr);
        let data = gen::by_name("wiki", 0.05, 7).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("val").unwrap();
        // Deep fixed window: the scanner parks a long batch backlog.
        let mut s = pool
            .stream(
                data.full(),
                BatchBy::Events(20),
                &mut m,
                StreamConfig::default().with_queue_depth(64),
            )
            .unwrap();
        assert!(s.num_batches_hint() > 64, "plan too small to form a backlog");

        let reader = reader_for(7);
        let tag = QosTag::new("reader", RequestClass::PointQuery, 1);
        let end = reader.snapshot().end_time() + 1;
        for node in 0..8u32 {
            let q = PointQuery::NeighborsBefore { node, t: end, k: 4 };
            let got = pool.point_query(&reader, &tag, q).unwrap();
            assert_eq!(got, reader.execute(&q));
        }
        // The stream still completes afterwards.
        let got = s.collect_all().unwrap();
        identical(&serial("val", 7), &got);
        let stats = pool.qos_stats();
        assert_eq!(stats.completed("reader", RequestClass::PointQuery), 8);
    }

    #[test]
    fn admission_cap_rejects_point_floods_with_backpressure() {
        let pool = ServingPool::with_scheduler(1, SchedulerKind::WeightedDrr);
        let data = gen::by_name("wiki", 0.05, 8).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("val").unwrap();
        // Occupy the single worker with a batch backlog so submitted
        // point queries actually queue.
        let mut s = pool
            .stream(
                data.full(),
                BatchBy::Events(50),
                &mut m,
                StreamConfig::default().with_queue_depth(32),
            )
            .unwrap();

        let reader = reader_for(8);
        let tag = QosTag::new("capped", RequestClass::PointQuery, 1).with_max_queued(1);
        let end = reader.snapshot().end_time() + 1;
        let q = PointQuery::EdgeLookup { src: 0, dst: 1, t: end };
        let mut tickets = Vec::new();
        let mut saw_backpressure = false;
        for _ in 0..50 {
            match pool.submit_point(&reader, &tag, q) {
                Ok(t) => tickets.push(t),
                Err(e) => {
                    assert!(matches!(e, TgmError::Backpressure(_)), "{e}");
                    saw_backpressure = true;
                    break;
                }
            }
        }
        assert!(saw_backpressure, "cap of 1 must reject a burst while the worker is busy");
        for t in tickets {
            t.wait().unwrap();
        }
        let _ = s.collect_all().unwrap();
    }
}
