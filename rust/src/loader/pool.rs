//! Shared batch-materialization worker pool (multi-tenant serving).
//!
//! [`ServingPool`] owns the worker threads that used to live inside
//! [`super::PrefetchLoader`]. Lifting them out lets **many concurrent
//! iterations** — typically one per tenant graph in a
//! [`crate::serving::TenantRouter`] — multiplex their materialization
//! jobs over one fixed set of threads instead of spawning a pool per
//! loader:
//!
//! * every iteration is a [`PooledStream`]: it plans its batches up
//!   front, snapshots its manager's stateless phase, and submits
//!   materialization jobs into the pool's shared FIFO queue;
//! * each stream keeps at most `queue_depth` jobs in flight (a sliding
//!   window over its plan), so one tenant can never flood the queue and
//!   starve the others, and total queued work stays proportional to the
//!   sum of the active streams' depths;
//! * workers execute jobs in submission order (materialize seed columns,
//!   run the stateless hook phase) and send each result back over the
//!   submitting stream's private bounded channel — results never cross
//!   between streams;
//! * the consumer side of each stream reorders arrivals into plan order
//!   and applies its own *stateful* hook phase, so per-tenant stateful
//!   hooks (e.g. the recency sampler) still observe batches strictly in
//!   order even though tenants share workers.
//!
//! **Determinism guarantee.** Exactly the [`super::PrefetchLoader`]
//! guarantee, per stream: batch boundaries come from the plan computed at
//! stream creation, stateless hooks draw per-batch RNG streams seeded by
//! the plan index, and the stateful phase runs in plan order on the
//! consuming thread. Because a stream holds its own
//! `Arc<StorageSnapshot>`, a tenant publishing a newer generation
//! mid-iteration never perturbs the stream pinned to the older one.
//!
//! Dropping a stream cancels its not-yet-executed jobs (workers skip
//! them via a shared flag). Dropping the pool enqueues one shutdown
//! token per worker behind the backlog and joins them; streams that
//! outlive their pool do not hang — already-delivered results drain,
//! and any further submission or wait surfaces a typed error (a racy
//! shutdown-while-serving may drop an in-flight result, but it reports
//! as an error, never silently).

use crate::error::{Result, TgmError};
use crate::graph::{DGraph, StorageSnapshot};
use crate::hooks::batch::MaterializedBatch;
use crate::hooks::manager::{HookManager, StatelessPipeline};
use crate::kernels;
use crate::loader::{affinity, materialize_window, plan_batches, BatchBy, BatchPlan};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// One worker-to-consumer message: plan position plus the materialized
/// batch (or the error that produced it).
type WorkerMsg = (usize, Result<MaterializedBatch>);

/// Per-stream materialization raw-speed counters: `(batches, bytes,
/// cycles)` — batch arenas built, their [`MaterializedBatch::byte_size`]
/// total, and [`kernels::cycles`] ticks spent building them. Shared with
/// workers the same way `busy` is; surfaced via
/// [`super::PrefetchStats`] and the profiler's materialization row.
type MatCounters = Arc<Mutex<(u64, u64, u64)>>;

/// How long a blocked consumer waits between pool-liveness checks. Only
/// paid when the pool died under a stream (or a worker is genuinely this
/// slow); the normal path never sees the timeout.
const POOL_LIVENESS_POLL: Duration = Duration::from_millis(50);

/// Adaptive streams reconsider their window every this many consumed
/// batches.
const ADAPT_EVERY: usize = 8;

/// Consumer-blocked time below this (per tuning window) counts as "the
/// queue always had a batch ready" — scheduler noise, not starvation.
const ADAPT_BLOCK_EPSILON: Duration = Duration::from_micros(200);

/// One unit of pool work: materialize one planned batch of one stream
/// and run that stream's stateless hook phase over it.
struct Job {
    storage: Arc<StorageSnapshot>,
    plan: BatchPlan,
    pipeline: StatelessPipeline,
    /// Plan position; echoed back so the consumer can reorder.
    seq: usize,
    /// Set when the submitting stream is dropped: skip without running.
    cancelled: Arc<AtomicBool>,
    /// Per-stream worker-busy accounting (for [`super::PrefetchStats`]).
    busy: Arc<Mutex<Duration>>,
    /// Per-stream materialization byte/cycle counters.
    mat: MatCounters,
    /// The submitting stream's private result channel.
    reply: SyncSender<WorkerMsg>,
}

/// Queue message: work, or an orderly per-worker shutdown token. Tokens
/// are enqueued by [`ServingPool::drop`] AFTER the backlog, so already
/// submitted jobs still execute; each worker consumes exactly one token
/// and exits. Boxed so the token variant stays word-sized.
enum Msg {
    Job(Box<Job>),
    Shutdown,
}

/// How a stream sizes its in-flight window (how many of its jobs may be
/// queued or finished-but-unconsumed at once).
///
/// The window only changes *scheduling* — how far ahead of the consumer
/// the workers may run — never the output: batches always arrive in
/// plan order with per-plan-index RNG seeds, so serial/pooled
/// determinism holds for any (even varying) depth.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueDepth {
    /// A fixed window (the escape hatch; the pre-adaptive behavior).
    Fixed(usize),
    /// Self-tuning window in `[min, max]`: starts at `min`, widens while
    /// the consumer is observed blocking on the pool (the same
    /// consumer-blocked vs worker-busy accounting the profiler reports)
    /// and narrows back while batches are always ready, bounding
    /// prefetched-batch memory to what the consumer actually needs.
    Adaptive {
        /// Smallest (and initial) window.
        min: usize,
        /// Largest window the tuner may grow to.
        max: usize,
    },
}

impl Default for QueueDepth {
    fn default() -> Self {
        QueueDepth::Adaptive { min: 2, max: 32 }
    }
}

impl QueueDepth {
    /// Smallest (and initial) window size.
    pub(crate) fn floor(self) -> usize {
        match self {
            QueueDepth::Fixed(d) => d.max(1),
            QueueDepth::Adaptive { min, .. } => min.max(1),
        }
    }

    /// Largest window size (reply channels are provisioned for this).
    pub(crate) fn cap(self) -> usize {
        match self {
            QueueDepth::Fixed(d) => d.max(1),
            QueueDepth::Adaptive { min, max } => max.max(min).max(1),
        }
    }

    pub(crate) fn is_adaptive(self) -> bool {
        matches!(self, QueueDepth::Adaptive { .. })
    }

    /// Raise both bounds to at least `n` (a dedicated pool should never
    /// idle for queue space).
    pub(crate) fn widened_to(self, n: usize) -> QueueDepth {
        match self {
            QueueDepth::Fixed(d) => QueueDepth::Fixed(d.max(n)),
            QueueDepth::Adaptive { min, max } => {
                QueueDepth::Adaptive { min: min.max(n), max: max.max(n) }
            }
        }
    }
}

/// Per-stream configuration (the pool itself only fixes the worker
/// count; everything batch-shaped is chosen per iteration).
#[derive(Debug, Clone)]
pub struct StreamConfig {
    /// Sliding-window sizing; adaptive by default (see [`QueueDepth`]).
    pub queue_depth: QueueDepth,
    /// Skip empty time buckets (mirrors the serial loader's default).
    pub skip_empty: bool,
    /// Max events per time-iteration batch (see
    /// [`super::DGDataLoader::with_event_cap`]).
    pub event_cap: usize,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            queue_depth: QueueDepth::default(),
            skip_empty: true,
            event_cap: usize::MAX,
        }
    }
}

impl StreamConfig {
    /// Fix the in-flight window size (disables the adaptive tuner).
    pub fn with_queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = QueueDepth::Fixed(depth.max(1));
        self
    }

    /// Self-tune the in-flight window within `[min, max]`.
    pub fn with_adaptive_depth(mut self, min: usize, max: usize) -> Self {
        self.queue_depth = QueueDepth::Adaptive { min: min.max(1), max: max.max(min).max(1) };
        self
    }

    /// Keep empty time buckets.
    pub fn with_empty_batches(mut self) -> Self {
        self.skip_empty = false;
        self
    }

    /// Split oversized time buckets to at most `cap` events.
    pub fn with_event_cap(mut self, cap: usize) -> Self {
        self.event_cap = cap.max(1);
        self
    }
}

/// A fixed set of worker threads multiplexing batch-materialization jobs
/// from any number of concurrent [`PooledStream`]s.
///
/// The pool may be dropped while streams are still alive: workers finish
/// the already-queued backlog, and surviving streams surface a typed
/// error (never a hang) on their next submission or wait.
pub struct ServingPool {
    /// Job queue entry point. `None` for a 0-worker pool (streams run
    /// their serial fallback). Wrapped in a `Mutex` so the pool is
    /// `Sync` and streams can be opened from any thread.
    tx: Mutex<Option<Sender<Msg>>>,
    /// Raised by `drop` before workers are joined; streams poll it so a
    /// wait on a dead pool fails fast instead of blocking forever.
    closed: Arc<AtomicBool>,
    handles: Vec<thread::JoinHandle<()>>,
    workers: usize,
}

impl ServingPool {
    /// Spawn `workers` threads. `0` creates an inert pool whose streams
    /// all run the serial in-place fallback (no threads, same output).
    /// Workers are CPU-pinned when the `TGM_PIN_WORKERS` env var asks
    /// for it (see [`affinity`]); [`ServingPool::with_affinity`] is the
    /// programmatic variant.
    pub fn new(workers: usize) -> ServingPool {
        ServingPool::with_affinity(workers, affinity::env_pin_plan().unwrap_or_default())
    }

    /// Spawn `workers` threads, pinning worker `i` to `cpus[i % len]`
    /// when `cpus` is non-empty. Pinning failures (CPU offline, cpuset
    /// restrictions, non-Linux platform) are silently ignored — the
    /// worker just runs unpinned; output is identical either way.
    pub fn with_affinity(workers: usize, cpus: Vec<usize>) -> ServingPool {
        let closed = Arc::new(AtomicBool::new(false));
        if workers == 0 {
            return ServingPool { tx: Mutex::new(None), closed, handles: Vec::new(), workers: 0 };
        }
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|w| {
                let rx = Arc::clone(&rx);
                let pin = if cpus.is_empty() { None } else { Some(cpus[w % cpus.len()]) };
                thread::spawn(move || {
                    if let Some(cpu) = pin {
                        let _ = affinity::pin_current_thread(cpu);
                    }
                    loop {
                        // Hold the lock only while dequeueing; execution
                        // runs unlocked so workers overlap.
                        let msg = {
                            let guard = rx.lock().unwrap_or_else(|e| e.into_inner());
                            guard.recv()
                        };
                        let job = match msg {
                            Ok(Msg::Job(job)) => job,
                            // One shutdown token per worker, or every
                            // sender (pool + all streams) is gone: exit.
                            Ok(Msg::Shutdown) | Err(_) => break,
                        };
                        if job.cancelled.load(Ordering::Relaxed) {
                            continue;
                        }
                        let t0 = Instant::now();
                        let c0 = kernels::cycles();
                        // A panicking hook must not strand the consumer
                        // waiting for a reply that will never come:
                        // convert the panic into a typed per-batch error.
                        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            materialize_window(&job.storage, &job.plan).and_then(|mut b| {
                                job.pipeline.run(&mut b, &job.storage, job.plan.index)?;
                                Ok(b)
                            })
                        }))
                        .unwrap_or_else(|_| {
                            Err(TgmError::Hook(
                                "a worker hook panicked while materializing this batch".into(),
                            ))
                        });
                        let cycles = kernels::cycles().wrapping_sub(c0);
                        if let Ok(mut d) = job.busy.lock() {
                            *d += t0.elapsed();
                        }
                        if let Ok(b) = &res {
                            if let Ok(mut m) = job.mat.lock() {
                                m.0 += 1;
                                m.1 += b.byte_size() as u64;
                                m.2 += cycles;
                            }
                        }
                        // A closed reply channel means the stream is
                        // gone; keep serving the other streams.
                        let _ = job.reply.send((job.seq, res));
                    }
                })
            })
            .collect();
        ServingPool { tx: Mutex::new(Some(tx)), closed, handles, workers }
    }

    /// Worker threads owned by the pool.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// A clone of the job-queue entry point (`None` once shut down or
    /// for a 0-worker pool).
    fn sender(&self) -> Option<Sender<Msg>> {
        self.tx.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Open one pooled iteration over `view`. Plans the batches,
    /// snapshots the active recipe's stateless phase, and submits the
    /// first window of jobs. The manager must be activated first (same
    /// contract as [`super::DGDataLoader`]).
    pub fn stream<'a>(
        &self,
        view: DGraph,
        by: BatchBy,
        manager: &'a mut HookManager,
        cfg: StreamConfig,
    ) -> Result<PooledStream<'a>> {
        let plans = plan_batches(&view, by, cfg.skip_empty, cfg.event_cap)?;
        let pipeline = manager.stateless_pipeline()?;
        let epoch = manager.registration_epoch();
        let storage = Arc::clone(view.storage());
        // Clamped so `cap + 1` and window arithmetic cannot overflow
        // (and a silly depth cannot pre-materialize a whole epoch).
        let depth_floor = cfg.queue_depth.floor().clamp(1, 1 << 20);
        let depth_cap = cfg.queue_depth.cap().clamp(depth_floor, 1 << 20);
        // An empty plan or an inert pool degrades to the serial path.
        let job_tx = if plans.is_empty() { None } else { self.sender() };
        let workers = if job_tx.is_some() { self.workers } else { 0 };
        // The window invariant (`submitted <= next_index + depth`, with
        // `next_index` advanced before topping up) allows `depth + 1`
        // unconsumed results at once; sizing the reply channel to hold
        // all of them — at the tuner's CAP, so shrinking the live window
        // can never strand an in-flight result — means a worker NEVER
        // blocks sending a result, so one slow stream cannot stall
        // workers other streams need.
        let (reply_tx, reply_rx) = sync_channel::<WorkerMsg>(depth_cap + 1);
        let mut stream = PooledStream {
            manager,
            storage,
            plans,
            pipeline,
            job_tx,
            pool_closed: Arc::clone(&self.closed),
            reply_tx,
            reply_rx,
            cancelled: Arc::new(AtomicBool::new(false)),
            busy: Arc::new(Mutex::new(Duration::ZERO)),
            mat: Arc::new(Mutex::new((0, 0, 0))),
            pending: HashMap::new(),
            submitted: 0,
            next_index: 0,
            blocked: Duration::ZERO,
            depth: depth_floor,
            depth_floor,
            depth_cap,
            adaptive: cfg.queue_depth.is_adaptive(),
            consumed_since_tune: 0,
            tuned_at_blocked: Duration::ZERO,
            tuned_at_busy: Duration::ZERO,
            workers,
            epoch,
        };
        stream.submit_window()?;
        Ok(stream)
    }
}

impl Drop for ServingPool {
    fn drop(&mut self) {
        // Surviving streams may still hold queue senders, so a plain
        // channel disconnect would never arrive: flag the shutdown (so
        // blocked/submitting streams error out fast), enqueue one token
        // per worker AFTER the backlog, then reap. Already-queued jobs
        // still execute and reply before the tokens are reached.
        self.closed.store(true, Ordering::SeqCst);
        if let Some(tx) = self.tx.lock().unwrap_or_else(|e| e.into_inner()).take() {
            for _ in 0..self.handles.len() {
                let _ = tx.send(Msg::Shutdown);
            }
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One iteration multiplexed over a [`ServingPool`]: yields batches in
/// plan order with the submitting manager's stateful phase applied on
/// the consuming thread.
pub struct PooledStream<'a> {
    manager: &'a mut HookManager,
    storage: Arc<StorageSnapshot>,
    plans: Vec<BatchPlan>,
    /// Stateless worker phase; also the serial fallback pipeline.
    pipeline: StatelessPipeline,
    /// `None` degrades to the serial in-place path.
    job_tx: Option<Sender<Msg>>,
    /// Shared with the producing pool; true once the pool shut down.
    pool_closed: Arc<AtomicBool>,
    reply_tx: SyncSender<WorkerMsg>,
    reply_rx: Receiver<WorkerMsg>,
    cancelled: Arc<AtomicBool>,
    busy: Arc<Mutex<Duration>>,
    /// Materialization raw-speed counters (worker- or serial-side).
    mat: MatCounters,
    /// Reorder buffer for batches that arrived ahead of plan order.
    pending: HashMap<usize, Result<MaterializedBatch>>,
    /// Plan positions submitted to the pool so far.
    submitted: usize,
    next_index: usize,
    blocked: Duration,
    /// Live in-flight window size (tuned when `adaptive`).
    depth: usize,
    depth_floor: usize,
    depth_cap: usize,
    adaptive: bool,
    /// Tuner bookkeeping: batches consumed and the blocked/busy totals
    /// observed at the last retune.
    consumed_since_tune: usize,
    tuned_at_blocked: Duration,
    tuned_at_busy: Duration,
    workers: usize,
    /// Manager registration epoch at stream creation; see
    /// [`PooledStream::next`].
    epoch: u64,
}

impl<'a> PooledStream<'a> {
    /// Top up the sliding window: submit jobs while fewer than `depth`
    /// of this stream's plans are in flight.
    fn submit_window(&mut self) -> Result<()> {
        let Some(tx) = &self.job_tx else { return Ok(()) };
        while self.submitted < self.plans.len()
            && self.submitted < self.next_index.saturating_add(self.depth)
        {
            // The closed check keeps a job from landing behind the
            // pool's shutdown tokens (where no worker would ever reach
            // it); the send error covers the fully-torn-down queue.
            if self.pool_closed.load(Ordering::SeqCst) {
                return Err(TgmError::Hook(
                    "serving pool shut down while a stream was still submitting".into(),
                ));
            }
            let job = Job {
                storage: Arc::clone(&self.storage),
                plan: self.plans[self.submitted].clone(),
                pipeline: self.pipeline.clone(),
                seq: self.submitted,
                cancelled: Arc::clone(&self.cancelled),
                busy: Arc::clone(&self.busy),
                mat: Arc::clone(&self.mat),
                reply: self.reply_tx.clone(),
            };
            if tx.send(Msg::Job(Box::new(job))).is_err() {
                return Err(TgmError::Hook(
                    "serving pool shut down while a stream was still submitting".into(),
                ));
            }
            self.submitted += 1;
        }
        Ok(())
    }

    /// Exact number of batches remaining.
    pub fn num_batches_hint(&self) -> usize {
        self.plans.len() - self.next_index
    }

    /// The snapshot this stream is pinned to.
    pub fn storage(&self) -> &Arc<StorageSnapshot> {
        &self.storage
    }

    /// The borrowed hook manager (stateful phase owner).
    pub fn manager_mut(&mut self) -> &mut HookManager {
        self.manager
    }

    /// Overlap accounting so far (read after draining for totals).
    pub fn stats(&self) -> super::PrefetchStats {
        let (mat_batches, mat_bytes, mat_cycles) =
            *self.mat.lock().unwrap_or_else(|e| e.into_inner());
        super::PrefetchStats {
            batches: self.plans.len(),
            workers: self.workers,
            worker_busy: *self.busy.lock().unwrap_or_else(|e| e.into_inner()),
            consumer_blocked: self.blocked,
            queue_depth: self.depth,
            mat_batches,
            mat_bytes,
            mat_cycles,
        }
    }

    /// Retune the adaptive window from the same counters the profiler's
    /// overlap report is built on: if the consumer spent a meaningful
    /// share of the last window blocked on the pool (vs what the
    /// workers were busy producing), widen so workers run further
    /// ahead; if every batch was ready on arrival, narrow back toward
    /// the floor to bound prefetched-batch memory. Scheduling only —
    /// batch bytes and order are depth-independent.
    fn maybe_retune(&mut self) {
        if !self.adaptive {
            return;
        }
        self.consumed_since_tune += 1;
        if self.consumed_since_tune < ADAPT_EVERY {
            return;
        }
        self.consumed_since_tune = 0;
        let busy_total = *self.busy.lock().unwrap_or_else(|e| e.into_inner());
        let blocked_delta = self.blocked.saturating_sub(self.tuned_at_blocked);
        let busy_delta = busy_total.saturating_sub(self.tuned_at_busy);
        self.tuned_at_blocked = self.blocked;
        self.tuned_at_busy = busy_total;
        if blocked_delta > ADAPT_BLOCK_EPSILON && blocked_delta * 4 > busy_delta {
            self.depth = (self.depth.saturating_mul(2)).min(self.depth_cap);
        } else if blocked_delta <= ADAPT_BLOCK_EPSILON && self.depth > self.depth_floor {
            self.depth -= 1;
        }
    }

    /// Next batch in plan order, or `None` when exhausted.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Option<Result<MaterializedBatch>> {
        if self.next_index >= self.plans.len() {
            return None;
        }
        // The worker pipeline is a point-in-time snapshot of the recipe;
        // registering hooks mid-iteration would silently diverge from
        // the serial loader, so fail loudly — and terminate the stream,
        // so error-tolerant consumers cannot spin on a sticky error.
        if self.manager.registration_epoch() != self.epoch {
            self.next_index = self.plans.len();
            return Some(Err(TgmError::Hook(
                "hooks were registered while a prefetch iteration was in flight; \
                 recreate the loader to pick them up"
                    .into(),
            )));
        }
        let idx = self.next_index;
        self.next_index += 1;

        // Serial fallback: materialize inline, no pool involved. The
        // materialization counters still accumulate so the profiler's
        // cycles/byte row covers serial and pooled runs alike.
        if self.job_tx.is_none() {
            let plan = self.plans[idx].clone();
            let c0 = kernels::cycles();
            let mut batch = match materialize_window(&self.storage, &plan) {
                Ok(b) => b,
                Err(e) => return Some(Err(e)),
            };
            if let Err(e) = self.pipeline.run(&mut batch, &self.storage, plan.index) {
                return Some(Err(e));
            }
            let cycles = kernels::cycles().wrapping_sub(c0);
            if let Ok(mut m) = self.mat.lock() {
                m.0 += 1;
                m.1 += batch.byte_size() as u64;
                m.2 += cycles;
            }
            if let Err(e) = self.manager.run_stateful_indexed(&mut batch, &self.storage, plan.index)
            {
                return Some(Err(e));
            }
            return Some(Ok(batch));
        }

        // Advancing the consumer index freed a window slot.
        if let Err(e) = self.submit_window() {
            self.next_index = self.plans.len();
            return Some(Err(e));
        }

        // Pull from the pool, reordering into plan order. The stream
        // holds its own `reply_tx`, so the reply channel cannot
        // disconnect while we wait — pool death is detected via the
        // shared `closed` flag instead (bounded by the liveness poll).
        let t0 = Instant::now();
        let res = loop {
            if let Some(r) = self.pending.remove(&idx) {
                break r;
            }
            match self.reply_rx.recv_timeout(POOL_LIVENESS_POLL) {
                Ok((i, r)) => {
                    if i == idx {
                        break r;
                    }
                    self.pending.insert(i, r);
                }
                Err(RecvTimeoutError::Timeout) => {
                    // Flag first, then one final drain attempt: results
                    // that landed before the shutdown are still valid.
                    if self.pool_closed.load(Ordering::SeqCst) {
                        if let Ok((i, r)) = self.reply_rx.try_recv() {
                            if i == idx {
                                break r;
                            }
                            self.pending.insert(i, r);
                            continue;
                        }
                        break Err(TgmError::Hook(
                            "serving pool shut down while this stream was waiting for a batch"
                                .into(),
                        ));
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    // Unreachable in practice: the stream itself owns a
                    // reply sender, so the channel cannot disconnect
                    // while it waits. Defensive error, not a panic.
                    break Err(TgmError::Hook(
                        "prefetch reply channel disconnected unexpectedly".into(),
                    ));
                }
            }
        };
        self.blocked += t0.elapsed();
        self.maybe_retune();

        match res {
            Ok(mut batch) => {
                let plan_index = self.plans[idx].index;
                if let Err(e) =
                    self.manager.run_stateful_indexed(&mut batch, &self.storage, plan_index)
                {
                    return Some(Err(e));
                }
                Some(Ok(batch))
            }
            Err(e) => Some(Err(e)),
        }
    }

    /// Drain all remaining batches.
    pub fn collect_all(&mut self) -> Result<Vec<MaterializedBatch>> {
        let mut out = Vec::new();
        while let Some(b) = self.next() {
            out.push(b?);
        }
        Ok(out)
    }
}

impl Drop for PooledStream<'_> {
    fn drop(&mut self) {
        // Not-yet-executed jobs of this stream are skipped by workers;
        // already-executing ones fail their reply send harmlessly.
        self.cancelled.store(true, Ordering::Relaxed);
        self.pending.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hooks::batch::assert_batches_identical as identical;
    use crate::hooks::recipes::{RecipeRegistry, RECIPE_TGB_LINK};
    use crate::io::gen;
    use crate::loader::DGDataLoader;

    fn serial(key: &str, seed: u64) -> Vec<MaterializedBatch> {
        let data = gen::by_name("wiki", 0.05, seed).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate(key).unwrap();
        DGDataLoader::new(data.full(), BatchBy::Events(100), &mut m)
            .unwrap()
            .collect_all()
            .unwrap()
    }

    #[test]
    fn two_streams_share_one_pool_deterministically() {
        // Two independent iterations (distinct datasets and stateful
        // managers) interleaved over the same 3-worker pool must each be
        // byte-identical to their serial runs.
        let pool = ServingPool::new(3);
        let d1 = gen::by_name("wiki", 0.05, 1).unwrap();
        let d2 = gen::by_name("wiki", 0.05, 2).unwrap();
        let mut m1 = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        let mut m2 = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m1.activate("train").unwrap();
        m2.activate("train").unwrap();
        let mut s1 = pool
            .stream(d1.full(), BatchBy::Events(100), &mut m1, StreamConfig::default())
            .unwrap();
        let mut s2 = pool
            .stream(d2.full(), BatchBy::Events(100), &mut m2, StreamConfig::default())
            .unwrap();

        // Interleave consumption so both windows stay in flight at once.
        let mut got1 = Vec::new();
        let mut got2 = Vec::new();
        loop {
            let a = s1.next();
            let b = s2.next();
            if let Some(x) = a {
                got1.push(x.unwrap());
            }
            if let Some(y) = b {
                got2.push(y.unwrap());
            }
            if got1.len() + got2.len() >= s1.stats().batches + s2.stats().batches {
                break;
            }
        }
        identical(&serial("train", 1), &got1);
        identical(&serial("train", 2), &got2);
    }

    #[test]
    fn pool_outlives_streams_and_serves_again() {
        let pool = ServingPool::new(2);
        for seed in [1u64, 2, 3] {
            let data = gen::by_name("wiki", 0.05, seed).unwrap();
            let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
            m.activate("val").unwrap();
            let mut s = pool
                .stream(data.full(), BatchBy::Events(100), &mut m, StreamConfig::default())
                .unwrap();
            let got = s.collect_all().unwrap();
            drop(s);
            identical(&serial("val", seed), &got);
        }
    }

    #[test]
    fn dropping_a_stream_mid_iteration_leaves_the_pool_healthy() {
        let pool = ServingPool::new(2);
        let data = gen::by_name("wiki", 0.05, 4).unwrap();
        {
            let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
            m.activate("val").unwrap();
            let mut s = pool
                .stream(
                    data.full(),
                    BatchBy::Events(50),
                    &mut m,
                    StreamConfig::default().with_queue_depth(1),
                )
                .unwrap();
            assert!(s.next().unwrap().is_ok());
            // Dropped with most of the plan unconsumed.
        }
        // The pool still serves a fresh stream to completion.
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("val").unwrap();
        let mut s = pool
            .stream(data.full(), BatchBy::Events(100), &mut m, StreamConfig::default())
            .unwrap();
        let got = s.collect_all().unwrap();
        identical(&serial("val", 4), &got);
    }

    #[test]
    fn pool_drop_with_live_stream_fails_fast_instead_of_hanging() {
        let data = gen::by_name("wiki", 0.05, 6).unwrap();

        // Every plan fits in the window: the backlog executes before the
        // pool's shutdown tokens, so the orphaned stream still completes.
        let mut m1 = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m1.activate("val").unwrap();
        let mut small = {
            let pool = ServingPool::new(2);
            pool.stream(
                data.full(),
                BatchBy::Events(100),
                &mut m1,
                StreamConfig::default().with_queue_depth(64),
            )
            .unwrap()
            // The pool is dropped here, while the stream lives on.
        };
        let got = small.collect_all().unwrap();
        identical(&serial("val", 6), &got);

        // More plans than the window: the stream must surface a typed
        // error promptly, not block forever on the dead pool.
        let mut m2 = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m2.activate("val").unwrap();
        let mut big = {
            let pool = ServingPool::new(2);
            pool.stream(
                data.full(),
                BatchBy::Events(20),
                &mut m2,
                StreamConfig::default().with_queue_depth(2),
            )
            .unwrap()
        };
        let mut saw_error = false;
        while let Some(b) = big.next() {
            if let Err(e) = b {
                assert!(e.to_string().contains("shut down"), "{e}");
                saw_error = true;
                break;
            }
        }
        assert!(saw_error, "a dead pool must surface as an error, not a hang");
    }

    #[test]
    fn adaptive_depth_is_bounded_and_byte_identical_to_fixed() {
        let serial = serial("train", 9);
        let pool = ServingPool::new(3);
        let data = gen::by_name("wiki", 0.05, 9).unwrap();

        let mut mf = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        mf.activate("train").unwrap();
        let mut fixed = pool
            .stream(
                data.full(),
                BatchBy::Events(100),
                &mut mf,
                StreamConfig::default().with_queue_depth(4),
            )
            .unwrap();
        let fixed_batches = fixed.collect_all().unwrap();
        assert_eq!(fixed.stats().queue_depth, 4, "fixed depth must not tune");
        identical(&serial, &fixed_batches);

        let mut ma = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        ma.activate("train").unwrap();
        let mut adaptive = pool
            .stream(
                data.full(),
                BatchBy::Events(100),
                &mut ma,
                StreamConfig::default().with_adaptive_depth(1, 64),
            )
            .unwrap();
        let mut got = Vec::new();
        while let Some(b) = adaptive.next() {
            let depth = adaptive.stats().queue_depth;
            assert!((1..=64).contains(&depth), "tuned depth {depth} out of bounds");
            got.push(b.unwrap());
        }
        identical(&serial, &got);
    }

    #[test]
    fn queue_depth_bounds() {
        assert_eq!(QueueDepth::Fixed(0).floor(), 1);
        assert_eq!(QueueDepth::Fixed(7).cap(), 7);
        let a = QueueDepth::Adaptive { min: 3, max: 2 };
        assert_eq!(a.floor(), 3);
        assert_eq!(a.cap(), 3, "an inverted range collapses to the floor");
        assert!(a.is_adaptive());
        assert_eq!(QueueDepth::Fixed(2).widened_to(5), QueueDepth::Fixed(5));
        assert_eq!(
            QueueDepth::Adaptive { min: 2, max: 4 }.widened_to(8),
            QueueDepth::Adaptive { min: 8, max: 8 }
        );
        assert_eq!(QueueDepth::default().floor(), 2);
    }

    #[test]
    fn zero_worker_pool_runs_serially() {
        let pool = ServingPool::new(0);
        assert_eq!(pool.workers(), 0);
        let data = gen::by_name("wiki", 0.05, 5).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("val").unwrap();
        let mut s = pool
            .stream(data.full(), BatchBy::Events(100), &mut m, StreamConfig::default())
            .unwrap();
        assert_eq!(s.stats().workers, 0);
        let got = s.collect_all().unwrap();
        identical(&serial("val", 5), &got);
        // The serial fallback still accounts materialization raw speed.
        let stats = s.stats();
        assert_eq!(stats.mat_batches as usize, got.len());
        let bytes: usize = got.iter().map(|b| b.byte_size()).sum();
        assert_eq!(stats.mat_bytes as usize, bytes);
    }

    #[test]
    fn pooled_streams_account_materialization_bytes() {
        let pool = ServingPool::new(2);
        let data = gen::by_name("wiki", 0.05, 7).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("val").unwrap();
        let mut s = pool
            .stream(data.full(), BatchBy::Events(100), &mut m, StreamConfig::default())
            .unwrap();
        let got = s.collect_all().unwrap();
        let stats = s.stats();
        assert_eq!(stats.mat_batches as usize, got.len());
        // Worker-side byte_size is measured before the consumer's
        // stateful phase adds attributes, so it lower-bounds the final
        // batch sizes and is strictly positive.
        let final_bytes: usize = got.iter().map(|b| b.byte_size()).sum();
        assert!(stats.mat_bytes > 0);
        assert!(stats.mat_bytes as usize <= final_bytes);
    }

    #[test]
    fn explicit_affinity_pool_serves_identically() {
        // Pinning is scheduling-only; even an absurd CPU list (pin
        // failures ignored) must leave output byte-identical.
        let pool = ServingPool::with_affinity(2, vec![0, 1 << 20]);
        let data = gen::by_name("wiki", 0.05, 8).unwrap();
        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        m.activate("train").unwrap();
        let mut s = pool
            .stream(data.full(), BatchBy::Events(100), &mut m, StreamConfig::default())
            .unwrap();
        let got = s.collect_all().unwrap();
        identical(&serial("train", 8), &got);
    }

    #[test]
    fn streams_open_from_other_threads() {
        // The pool is Sync: scoped threads open and drain their own
        // streams concurrently against one shared pool.
        let pool = ServingPool::new(4);
        let results: Vec<Vec<MaterializedBatch>> = thread::scope(|scope| {
            let handles: Vec<_> = (1u64..=3)
                .map(|seed| {
                    let pool = &pool;
                    scope.spawn(move || {
                        let data = gen::by_name("wiki", 0.05, seed).unwrap();
                        let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
                        m.activate("train").unwrap();
                        let mut s = pool
                            .stream(
                                data.full(),
                                BatchBy::Events(100),
                                &mut m,
                                StreamConfig::default(),
                            )
                            .unwrap();
                        s.collect_all().unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (seed, got) in (1u64..=3).zip(&results) {
            identical(&serial("train", seed), got);
        }
    }
}
