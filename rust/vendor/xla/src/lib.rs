//! Offline stub of the `xla` (PJRT) bindings the `tgm` runtime layer is
//! written against.
//!
//! The real crate wraps the XLA/PJRT C API and needs a multi-gigabyte
//! native library that cannot be fetched in this environment. This stub
//! keeps the same API surface so the rest of the crate compiles and the
//! *host-side* pieces ([`Literal`] construction, byte round-trips, dtype
//! checks) behave exactly like the real thing — they are plain memory
//! operations. Device-side entry points ([`PjRtClient::cpu`],
//! compilation, execution) return a descriptive [`Error`] instead, so
//! every pipeline that needs compiled artifacts skips gracefully (the
//! integration tests and benches already probe for this).
//!
//! Swapping in the real bindings is a one-line change in
//! `rust/Cargo.toml`; no `tgm` source references differ.

use std::borrow::Borrow;
use std::fmt;

/// Error type mirroring the real crate's.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    /// Create an error with a message.
    pub fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }

    fn unavailable(what: &str) -> Error {
        Error(format!("{what}: PJRT is unavailable in this offline build (xla stub)"))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Crate-local result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Element types we can represent. Only `F32`/`S32` carry data in the
/// stub; the remaining variants exist so dtype dispatch in callers stays
/// exhaustive-with-fallback, as with the real bindings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U32,
    F32,
    F64,
}

impl ElementType {
    fn byte_size(self) -> Option<usize> {
        match self {
            ElementType::Pred => Some(1),
            ElementType::S32 | ElementType::U32 | ElementType::F32 => Some(4),
            ElementType::S64 | ElementType::F64 => Some(8),
        }
    }
}

/// Host-native element types a [`Literal`] can be viewed as.
pub trait NativeType: Copy {
    /// The dtype tag of this native type.
    const TY: ElementType;
    /// Bytes per element.
    const SIZE: usize;
    /// Decode one element from little-endian bytes.
    fn from_le_slice(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    const SIZE: usize = 4;
    fn from_le_slice(bytes: &[u8]) -> f32 {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    const SIZE: usize = 4;
    fn from_le_slice(bytes: &[u8]) -> i32 {
        i32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

/// A host literal: dtype + shape + row-major little-endian bytes.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    /// Build a literal from a dtype, shape and raw little-endian bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elem = ty
            .byte_size()
            .ok_or_else(|| Error::new(format!("unsupported element type {ty:?}")))?;
        let expect: usize = shape.iter().product::<usize>() * elem;
        if data.len() != expect {
            return Err(Error::new(format!(
                "literal data has {} bytes, shape {shape:?} of {ty:?} needs {expect}",
            )));
        }
        Ok(Literal { ty, shape: shape.to_vec(), data: data.to_vec() })
    }

    /// Element type of the literal.
    pub fn ty(&self) -> Result<ElementType> {
        Ok(self.ty)
    }

    /// Shape of the literal.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total number of elements.
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    /// Copy the data out as a typed host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::TY != self.ty {
            return Err(Error::new(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(self.data.chunks_exact(T::SIZE).map(T::from_le_slice).collect())
    }

    /// First element of the literal, typed.
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        if T::TY != self.ty {
            return Err(Error::new(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        if self.data.len() < T::SIZE {
            return Err(Error::new("empty literal has no first element"));
        }
        Ok(T::from_le_slice(&self.data[..T::SIZE]))
    }

    /// Decompose a tuple literal. The stub never constructs tuples (they
    /// only arise from device execution), so this always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::new("stub literal is not a tuple (no device execution available)"))
    }
}

/// Parsed HLO module (device-side only; unavailable in the stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    /// Parse an HLO text file. Unavailable offline.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapper.
#[derive(Debug)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    /// Wrap a parsed HLO proto.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// PJRT client handle. Construction fails in the stub.
#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Create a CPU client. Unavailable offline — callers are expected to
    /// treat this as "no runtime present" and skip device work.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    /// Platform name of the client.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation. Unavailable offline.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// A compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals. Unavailable offline.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal. Unavailable offline.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip_f32() {
        let data: Vec<u8> = [1.0f32, -2.5, 3.25].iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &data).unwrap();
        assert_eq!(lit.element_count(), 3);
        assert_eq!(lit.ty().unwrap(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.25]);
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 1.0);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_size_validation() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0; 4])
            .is_err());
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::S32, &[2], &[0; 8])
            .is_ok());
    }

    #[test]
    fn device_paths_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
