//! Integration tests across storage + hooks + loader + runtime +
//! coordinator. Tests needing compiled artifacts skip gracefully when
//! `make artifacts` hasn't run (CI without the Python toolchain).

use std::sync::Arc;
use tgm::coordinator::{evaluate_edgebank, Pipeline, PipelineConfig, Split};
use tgm::graph::{
    discretize, discretize_utg, DGData, ReduceOp, SealPolicy, SegmentedStorage, SnapshotCell,
    Task,
};
use tgm::hooks::recipes::{RecipeRegistry, RECIPE_TGB_LINK};
use tgm::hooks::MaterializedBatch;
use tgm::io::gen;
use tgm::io::stream::{EventSource, ReplaySource};
use tgm::loader::{BatchBy, DGDataLoader, PrefetchConfig, PrefetchLoader, ServingPool, StreamConfig};
use tgm::models::EdgeBankMode;
use tgm::persist::{self, Compactor, CompactorConfig, DurabilityPolicy, SegmentBacking};
use tgm::replica::{DirTransport, Replica, ReplicaConfig};
use tgm::runtime::XlaEngine;
use tgm::serving::{ReadHandle, ServingConfig, TenantConfig, TenantId, TenantRouter};
use tgm::util::TimeGranularity;

fn engine() -> Option<XlaEngine> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    XlaEngine::cpu(dir).ok()
}

#[test]
fn full_data_path_without_runtime() {
    // storage -> splits -> hooks -> loader over a surrogate dataset.
    let data = gen::by_name("wiki", 0.05, 1).unwrap();
    let splits = data.split().unwrap();
    let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
    m.activate("train").unwrap();
    let mut loader = DGDataLoader::new(splits.train.clone(), BatchBy::Events(100), &mut m).unwrap();
    let batches = loader.collect_all().unwrap();
    assert!(!batches.is_empty());
    let total: usize = batches.iter().map(|b| b.num_edges()).sum();
    assert_eq!(total, splits.train.num_edges());
    for b in &batches {
        assert!(b.has(tgm::hooks::attr::NEGATIVES));
        assert!(b.has(tgm::hooks::attr::NEIGHBORS));
    }
}

/// Acceptance check for the prefetch pipeline: byte-identical
/// `MaterializedBatch` contents vs the serial loader, for both event and
/// time iteration, with >= 2 workers, through the public API.
#[test]
fn prefetch_loader_is_deterministic_end_to_end() {
    fn identical(a: &[MaterializedBatch], b: &[MaterializedBatch]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!((x.start, x.end), (y.start, y.end));
            assert_eq!(x.src, y.src);
            assert_eq!(x.dst, y.dst);
            assert_eq!(x.ts, y.ts);
            assert_eq!(x.edge_indices, y.edge_indices);
            assert_eq!(x.attr_names(), y.attr_names());
            for name in x.attr_names() {
                assert_eq!(x.get(name).unwrap(), y.get(name).unwrap(), "attr `{name}`");
            }
        }
    }

    let data = gen::by_name("wiki", 0.05, 21).unwrap();
    for by in [BatchBy::Events(100), BatchBy::Time(TimeGranularity::Day)] {
        for key in ["train", "val"] {
            let mut ms = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
            ms.activate(key).unwrap();
            let serial = DGDataLoader::new(data.full(), by, &mut ms)
                .unwrap()
                .with_event_cap(150)
                .collect_all()
                .unwrap();
            assert!(serial.len() > 2, "{by:?}/{key}: want several batches");

            let mut mp = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
            mp.activate(key).unwrap();
            let prefetched = PrefetchLoader::new(
                data.full(),
                by,
                &mut mp,
                PrefetchConfig::default().with_workers(3).with_event_cap(150),
            )
            .unwrap()
            .collect_all()
            .unwrap();
            identical(&serial, &prefetched);
        }
    }
}

fn assert_identical(a: &[MaterializedBatch], b: &[MaterializedBatch]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!((x.start, x.end), (y.start, y.end));
        assert_eq!(x.src, y.src);
        assert_eq!(x.dst, y.dst);
        assert_eq!(x.ts, y.ts);
        assert_eq!(x.edge_indices, y.edge_indices);
        assert_eq!(x.node_events, y.node_events);
        assert_eq!(x.attr_names(), y.attr_names());
        for name in x.attr_names() {
            assert_eq!(x.get(name).unwrap(), y.get(name).unwrap(), "attr `{name}`");
        }
    }
}

/// Replay a dataset's event log through a segmented store (many small
/// sealed segments) and return it as a dataset over the final snapshot.
fn streamed_copy(data: &DGData, seal_every: usize) -> DGData {
    let mut store = SegmentedStorage::new(
        data.storage().num_nodes(),
        SealPolicy::by_events(seal_every),
    )
    .with_granularity(data.storage().granularity());
    let mut source = ReplaySource::from_data(data);
    loop {
        let chunk = source.next_chunk(777);
        if chunk.is_empty() {
            break;
        }
        for ev in chunk {
            store.append(ev).unwrap();
        }
    }
    store.seal().unwrap();
    DGData::from_snapshot(store.snapshot().unwrap(), data.name(), data.task())
}

/// Acceptance criterion for the segmented-storage refactor: a training
/// run over a snapshot of a fully appended-then-sealed stream produces
/// byte-identical batches — event and time iteration, serial and prefetch
/// at >= 2 workers — to the same data built via `GraphStorage::from_events`.
#[test]
fn streamed_snapshot_matches_from_events_serial_and_prefetch() {
    let one_shot = gen::by_name("wiki", 0.05, 33).unwrap();
    let streamed = streamed_copy(&one_shot, 97);
    assert!(
        streamed.storage().num_segments() > 4,
        "want a genuinely multi-segment snapshot, got {}",
        streamed.storage().num_segments()
    );

    for by in [BatchBy::Events(100), BatchBy::Time(TimeGranularity::Day)] {
        for key in ["train", "val"] {
            let mut ms = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
            ms.activate(key).unwrap();
            let reference = DGDataLoader::new(one_shot.full(), by, &mut ms)
                .unwrap()
                .with_event_cap(150)
                .collect_all()
                .unwrap();
            assert!(reference.len() > 2, "{by:?}/{key}: want several batches");

            // Serial loader over the streamed snapshot.
            let mut mt = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
            mt.activate(key).unwrap();
            let serial = DGDataLoader::new(streamed.full(), by, &mut mt)
                .unwrap()
                .with_event_cap(150)
                .collect_all()
                .unwrap();
            assert_identical(&reference, &serial);

            // Prefetch loader over the streamed snapshot at >= 2 workers.
            for workers in [2usize, 4] {
                let mut mp = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
                mp.activate(key).unwrap();
                let prefetched = PrefetchLoader::new(
                    streamed.full(),
                    by,
                    &mut mp,
                    PrefetchConfig::default().with_workers(workers).with_event_cap(150),
                )
                .unwrap()
                .collect_all()
                .unwrap();
                assert_identical(&reference, &prefetched);
            }
        }
    }
}

/// Node events stream through segments too (genre carries them), and the
/// materialized `node_events` column survives the logical-offset layer.
#[test]
fn streamed_node_events_match_one_shot() {
    let one_shot = gen::by_name("genre", 0.03, 7).unwrap();
    assert!(one_shot.storage().num_node_events() > 0);
    let streamed = streamed_copy(&one_shot, 211);
    assert_eq!(
        streamed.storage().num_node_events(),
        one_shot.storage().num_node_events()
    );

    let mut m1 = RecipeRegistry::build(tgm::hooks::RECIPE_TGB_NODE).unwrap();
    m1.activate("train").unwrap();
    let a = DGDataLoader::new(one_shot.full(), BatchBy::Events(128), &mut m1)
        .unwrap()
        .collect_all()
        .unwrap();
    let mut m2 = RecipeRegistry::build(tgm::hooks::RECIPE_TGB_NODE).unwrap();
    m2.activate("train").unwrap();
    let b = DGDataLoader::new(streamed.full(), BatchBy::Events(128), &mut m2)
        .unwrap()
        .collect_all()
        .unwrap();
    assert_identical(&a, &b);
}

/// Acceptance criterion for the sharded-serving tentpole: a reader that
/// pinned generation *G* must yield byte-identical batches — serial and
/// pooled — even when the tenant publishes *G+1* mid-epoch, and a fresh
/// serve must observe *G+1*.
#[test]
fn pinned_generation_streams_are_immune_to_mid_epoch_publishes() {
    let data = gen::by_name("wiki", 0.05, 55).unwrap();
    let mut source = ReplaySource::from_data(&data);
    let total = source.len();
    let first = source.next_chunk((total * 3) / 5);
    let rest = source.next_chunk(usize::MAX);
    assert!(!rest.is_empty());

    let mut router = TenantRouter::new();
    let id = TenantId::from("wiki");
    router
        .add_tenant(
            id.clone(),
            TenantConfig::new(data.storage().num_nodes())
                .with_seal(SealPolicy::by_events(120))
                .with_granularity(data.storage().granularity()),
        )
        .unwrap();
    router.ingest(&id, first).unwrap();
    let pinned = router.publish(&id).unwrap();

    // Serial reference over generation G.
    let gd = DGData::from_snapshot(Arc::clone(&pinned), "wiki-g", Task::LinkPrediction);
    let mut ms = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
    ms.activate("val").unwrap();
    let reference =
        DGDataLoader::new(gd.full(), BatchBy::Events(64), &mut ms).unwrap().collect_all().unwrap();
    assert!(reference.len() > 4, "want a multi-batch epoch, got {}", reference.len());

    // Pooled stream pinned to G: consume part of the epoch...
    let pool = ServingPool::new(3);
    let mut mp = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
    mp.activate("val").unwrap();
    let mut stream = router
        .serve(&pool, &id, BatchBy::Events(64), &mut mp, StreamConfig::default())
        .unwrap();
    let mut got = Vec::new();
    for _ in 0..3 {
        got.push(stream.next().unwrap().unwrap());
    }

    // ...then swap the published snapshot mid-epoch.
    router.ingest(&id, rest).unwrap();
    let newer = router.publish(&id).unwrap();
    assert!(newer.generation() > pinned.generation());
    assert_eq!(router.pin(&id).unwrap().generation(), newer.generation());

    // The in-flight stream still yields generation-G bytes only.
    while let Some(b) = stream.next() {
        got.push(b.unwrap());
    }
    drop(stream);
    assert_identical(&reference, &got);

    // The still-held pin replays the identical serial epoch, too.
    let gd2 = DGData::from_snapshot(Arc::clone(&pinned), "wiki-g2", Task::LinkPrediction);
    let mut m2 = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
    m2.activate("val").unwrap();
    let replay =
        DGDataLoader::new(gd2.full(), BatchBy::Events(64), &mut m2).unwrap().collect_all().unwrap();
    assert_identical(&reference, &replay);

    // A fresh serve pins G+1 and sees the whole graph.
    let mut mf = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
    mf.activate("val").unwrap();
    let mut s2 = router
        .serve(&pool, &id, BatchBy::Events(64), &mut mf, StreamConfig::default())
        .unwrap();
    let served: usize = s2.collect_all().unwrap().iter().map(|b| b.num_edges()).sum();
    assert_eq!(served, data.storage().num_edges());
}

/// Acceptance criterion for the durable-segment-store tentpole, part 1:
/// a durable store killed at an arbitrary point mid-ingest recovers to
/// exactly the acknowledged prefix. The kill is simulated by truncating
/// the WAL at randomized byte offsets — everything past the cut never
/// reached disk — and recovery must yield precisely the complete-record
/// prefix, byte-identical to an in-memory store fed the same events.
#[test]
fn wal_truncated_at_arbitrary_offsets_recovers_the_acknowledged_prefix() {
    const WAL_HEADER: usize = 20; // magic(8) + version(4) + epoch(8)
    const SEAL_EVERY: usize = 97;
    let data = gen::by_name("wiki", 0.05, 44).unwrap();
    let g = data.storage().granularity();
    let n_nodes = data.storage().num_nodes();
    let dir = std::env::temp_dir().join(format!("tgm_it_walcut_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut source = ReplaySource::from_data(&data);
    let events = source.next_chunk(usize::MAX);
    let cut = (events.len() * 2) / 3;

    {
        let mut st = SegmentedStorage::new(n_nodes, SealPolicy::by_events(SEAL_EVERY))
            .with_granularity(g)
            .with_durability(DurabilityPolicy::new(&dir))
            .unwrap();
        for ev in &events[..cut] {
            st.append(ev.clone()).unwrap();
        }
        assert!(st.num_sealed_segments() >= 3);
        assert!(st.pending_edges() + st.pending_node_events() > 0, "want a live WAL tail");
        // Crash: drop without sealing — nothing is flushed on drop that
        // the acknowledged appends did not already flush.
    }
    let wal_path = dir.join("wal.log");
    let wal = std::fs::read(&wal_path).unwrap();
    assert!(wal.len() > WAL_HEADER);

    let reference = |k: usize| -> DGData {
        let mut st = SegmentedStorage::new(n_nodes, SealPolicy::by_events(SEAL_EVERY))
            .with_granularity(g);
        for ev in &events[..k] {
            st.append(ev.clone()).unwrap();
        }
        DGData::from_snapshot(st.snapshot().unwrap(), "ref", Task::LinkPrediction)
    };

    let mut rng = tgm::util::Rng::new(4242);
    let mut offsets: Vec<usize> = (0..10)
        .map(|_| rng.range(WAL_HEADER as i64, wal.len() as i64 + 1) as usize)
        .collect();
    offsets.push(WAL_HEADER); // fully torn tail: sealed data only
    offsets.push(wal.len()); // untouched tail: every acknowledged event
    offsets.sort_unstable();
    let mut last_recovered = 0usize;
    for cutoff in offsets {
        std::fs::write(&wal_path, &wal[..cutoff]).unwrap();
        let mut rec = persist::recover(
            SealPolicy::by_events(SEAL_EVERY),
            DurabilityPolicy::new(&dir),
        )
        .unwrap();
        let snap = rec.snapshot().unwrap();
        let recovered = snap.num_edges() + snap.num_node_events();
        assert!(recovered >= last_recovered, "prefix must grow with surviving bytes");
        assert!(recovered <= cut);
        last_recovered = recovered;
        let exp = reference(recovered);
        assert_eq!(snap.edge_ts(), exp.storage().edge_ts(), "cutoff {cutoff}");
        assert_eq!(snap.edge_src(), exp.storage().edge_src(), "cutoff {cutoff}");
        assert_eq!(snap.edge_dst(), exp.storage().edge_dst(), "cutoff {cutoff}");
        assert_eq!(snap.edge_feats(), exp.storage().edge_feats(), "cutoff {cutoff}");
        if cutoff == wal.len() {
            assert_eq!(recovered, cut, "an untouched WAL recovers everything acknowledged");
        }
    }

    // A cut inside the header (impossible from a crash — the header is
    // rename-protected — hence corruption) is a typed error.
    std::fs::write(&wal_path, &wal[..WAL_HEADER - 5]).unwrap();
    assert!(persist::recover(
        SealPolicy::by_events(SEAL_EVERY),
        DurabilityPolicy::new(&dir)
    )
    .is_err());
}

/// Acceptance criterion, part 2: streamed-equals-recovered determinism.
/// A recovered store serves byte-identical hooked batches to an
/// uninterrupted one-shot build of the same prefix — serial and
/// prefetch at >= 2 workers.
#[test]
fn recovered_store_serves_byte_identical_batches_serial_and_prefetch() {
    let data = gen::by_name("wiki", 0.05, 45).unwrap();
    let dir = std::env::temp_dir().join(format!("tgm_it_recserve_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    {
        let mut st = SegmentedStorage::new(
            data.storage().num_nodes(),
            SealPolicy::by_events(111),
        )
        .with_granularity(data.storage().granularity())
        .with_durability(DurabilityPolicy::new(&dir))
        .unwrap();
        let mut source = ReplaySource::from_data(&data);
        for ev in source.next_chunk(usize::MAX) {
            st.append(ev).unwrap();
        }
    } // crash
    let mut rec =
        persist::recover(SealPolicy::by_events(111), DurabilityPolicy::new(&dir)).unwrap();
    let recovered = DGData::from_snapshot(rec.snapshot().unwrap(), "rec", data.task());

    for key in ["train", "val"] {
        let mut ms = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        ms.activate(key).unwrap();
        let one_shot = DGDataLoader::new(data.full(), BatchBy::Events(100), &mut ms)
            .unwrap()
            .collect_all()
            .unwrap();
        assert!(one_shot.len() > 2);

        let mut mt = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        mt.activate(key).unwrap();
        let serial = DGDataLoader::new(recovered.full(), BatchBy::Events(100), &mut mt)
            .unwrap()
            .collect_all()
            .unwrap();
        assert_identical(&one_shot, &serial);

        for workers in [2usize, 4] {
            let mut mp = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
            mp.activate(key).unwrap();
            let prefetched = PrefetchLoader::new(
                recovered.full(),
                BatchBy::Events(100),
                &mut mp,
                PrefetchConfig::default().with_workers(workers),
            )
            .unwrap()
            .collect_all()
            .unwrap();
            assert_identical(&one_shot, &prefetched);
        }
    }
}

/// Acceptance criterion, part 3: background compaction publishes
/// generations without blocking appends. An appender keeps sealing new
/// segments while the compactor merges and publishes concurrently; at
/// the end the store holds every appended event, the published
/// generations advanced monotonically, and a generation pinned before
/// compaction still reads its original bytes.
#[test]
fn appends_continue_during_background_compaction() {
    let data = gen::by_name("wiki", 0.05, 46).unwrap();
    let dir = std::env::temp_dir().join(format!("tgm_it_bgcompact_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut source = ReplaySource::from_data(&data);
    let events = source.next_chunk(usize::MAX);
    let total = events.len();

    let mut st = SegmentedStorage::new(data.storage().num_nodes(), SealPolicy::by_events(50))
        .with_granularity(data.storage().granularity())
        .with_durability(DurabilityPolicy::new(&dir))
        .unwrap();
    // Seed enough sealed segments that compaction has work immediately.
    let seed = total / 4;
    for ev in &events[..seed] {
        st.append(ev.clone()).unwrap();
    }
    let cell = SnapshotCell::new();
    let pinned = st.publish_to(&cell).unwrap();
    let pinned_ts = pinned.edge_ts();
    let store = Arc::new(std::sync::Mutex::new(st));

    let compactor = Compactor::spawn(
        Arc::clone(&store),
        cell.clone(),
        CompactorConfig {
            min_sealed: 2,
            interval: std::time::Duration::from_millis(1),
            ..CompactorConfig::default()
        },
    );

    // Appender: short writer locks, publishing as it goes — never
    // waiting on a merge (merges happen off-lock in the compactor).
    let mut generations = vec![pinned.generation()];
    for chunk in events[seed..].chunks(200) {
        let mut w = store.lock().unwrap();
        for ev in chunk {
            w.append(ev.clone()).unwrap();
        }
        let snap = w.publish_to(&cell).unwrap();
        generations.push(snap.generation());
    }
    assert!(generations.windows(2).all(|w| w[0] < w[1]), "generations advance");

    // Let the (tiered) compactor drain the low level, then stop it. The
    // fixpoint keeps O(fanout x log n) segments rather than 1, so the
    // exit condition is "a round ran and the stack shrank", not "one
    // segment left".
    let t0 = std::time::Instant::now();
    while t0.elapsed() < std::time::Duration::from_secs(10) {
        if compactor.compactions() > 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let rounds = compactor.compactions();
    assert!(rounds > 0, "compactor never ran: {:?}", compactor.last_error());
    assert!(compactor.last_error().is_none(), "{:?}", compactor.last_error());
    compactor.stop();

    // Nothing lost, nothing reordered; the early pin is untouched.
    let mutex =
        Arc::try_unwrap(store).unwrap_or_else(|_| panic!("compactor still holds the store"));
    let mut st = mutex.into_inner().unwrap();
    let snap = st.snapshot().unwrap();
    assert_eq!(snap.num_edges() + snap.num_node_events(), total);
    assert_eq!(snap.edge_ts(), data.storage().edge_ts());
    assert_eq!(snap.edge_feats(), data.storage().edge_feats());
    assert_eq!(pinned.edge_ts(), pinned_ts, "pinned generations are immutable");
    let published = cell.pin().unwrap();
    assert!(published.generation() >= *generations.last().unwrap());

    // And the whole thing survives a restart.
    drop(st);
    let mut rec =
        persist::recover(SealPolicy::by_events(50), DurabilityPolicy::new(&dir)).unwrap();
    assert_eq!(rec.snapshot().unwrap().edge_ts(), data.storage().edge_ts());
}

/// Tentpole (a) property: tiered compaction at random fanouts — driven
/// incrementally during ingest, exactly as the background compactor
/// drives it — converges to byte-identical snapshots (and recovered
/// directories) to one full compaction of the same stream, while
/// rewriting fewer bytes.
#[test]
fn tiered_compaction_matches_full_compaction_at_random_fanouts() {
    let data = gen::by_name("wiki", 0.05, 47).unwrap();
    let mut source = ReplaySource::from_data(&data);
    let events = source.next_chunk(usize::MAX);
    let n_nodes = data.storage().num_nodes();
    let g = data.storage().granularity();
    let mut rng = tgm::util::Rng::new(4747);

    // Full-compaction reference, durable.
    let full_dir = std::env::temp_dir().join(format!("tgm_it_tier_full_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&full_dir);
    let mut full = SegmentedStorage::new(n_nodes, SealPolicy::by_events(64))
        .with_granularity(g)
        .with_durability(DurabilityPolicy::new(&full_dir))
        .unwrap();
    for ev in &events {
        full.append(ev.clone()).unwrap();
    }
    full.seal().unwrap();
    full.compact().unwrap();
    let reference = full.snapshot().unwrap();
    let full_bytes = full.compaction_bytes();
    drop(full);

    for trial in 0..4u64 {
        let fanout = rng.range(2, 7) as usize;
        let dir = std::env::temp_dir()
            .join(format!("tgm_it_tier_{trial}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut st = SegmentedStorage::new(n_nodes, SealPolicy::by_events(64))
            .with_granularity(g)
            .with_durability(DurabilityPolicy::new(&dir))
            .unwrap();
        for ev in &events {
            if st.append(ev.clone()).unwrap() {
                // A seal landed: drive tiering to its fixpoint, exactly
                // like the background compactor's re-scan loop.
                while st.compact_tiered(fanout).unwrap().is_some() {}
            }
        }
        st.seal().unwrap();
        while st.compact_tiered(fanout).unwrap().is_some() {}
        let snap = st.snapshot().unwrap();
        assert_eq!(snap.edge_ts(), reference.edge_ts(), "fanout {fanout}");
        assert_eq!(snap.edge_src(), reference.edge_src(), "fanout {fanout}");
        assert_eq!(snap.edge_dst(), reference.edge_dst(), "fanout {fanout}");
        assert_eq!(snap.edge_feats(), reference.edge_feats(), "fanout {fanout}");
        assert_eq!(snap.num_node_events(), reference.num_node_events(), "fanout {fanout}");
        // Write-amp sanity: incremental tiering rewrites each event at
        // most ~once per size level (log_fanout of ~120 seals <= 7), so
        // it stays within a small constant of ONE full merge — where an
        // incremental *full* strategy would be ~60x (quadratic). The
        // tight comparison lives in `ablation.persist`.
        let sealed = snap.num_segments();
        assert!(
            st.compaction_bytes() <= full_bytes * 16,
            "fanout {fanout}: tiered wrote {} vs one full merge {full_bytes} \
             ({sealed} segments) — quadratic write amplification?",
            st.compaction_bytes()
        );
        drop(st);

        // The tiered directory recovers byte-identically too.
        let mut rec = persist::recover(
            SealPolicy::by_events(64),
            DurabilityPolicy::new(&dir),
        )
        .unwrap();
        assert_eq!(rec.snapshot().unwrap().edge_ts(), reference.edge_ts());
        drop(rec);
        let _ = std::fs::remove_dir_all(&dir);
    }
    let _ = std::fs::remove_dir_all(&full_dir);
}

/// Tentpole (b) acceptance: an mmap-backed store serves hooked batches
/// byte-identical to the heap-backed recovery of the same directory —
/// serial and prefetch at >= 2 workers.
#[test]
fn mmap_backed_store_serves_byte_identical_batches_serial_and_prefetch() {
    let data = gen::by_name("wiki", 0.05, 48).unwrap();
    let dir = std::env::temp_dir().join(format!("tgm_it_mmapserve_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    {
        let mut st = SegmentedStorage::new(
            data.storage().num_nodes(),
            SealPolicy::by_events(120),
        )
        .with_granularity(data.storage().granularity())
        .with_durability(DurabilityPolicy::new(&dir))
        .unwrap();
        let mut source = ReplaySource::from_data(&data);
        for ev in source.next_chunk(usize::MAX) {
            st.append(ev).unwrap();
        }
    } // crash

    let mut heap =
        persist::recover(SealPolicy::by_events(120), DurabilityPolicy::new(&dir)).unwrap();
    let heap_data = DGData::from_snapshot(heap.snapshot().unwrap(), "heap", data.task());
    drop(heap); // release the directory lock for the mmap reopen

    let mut mapped = persist::recover(
        SealPolicy::by_events(120),
        DurabilityPolicy::new(&dir).with_backing(SegmentBacking::Mmap),
    )
    .unwrap();
    let snap = mapped.snapshot().unwrap();
    if tgm::persist::mmap::supported() {
        assert!(snap.num_mapped_segments() > 0, "sealed segments must be mmap-served");
    }
    let mapped_data = DGData::from_snapshot(snap, "mapped", data.task());

    for key in ["train", "val"] {
        let mut mh = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        mh.activate(key).unwrap();
        let reference = DGDataLoader::new(heap_data.full(), BatchBy::Events(100), &mut mh)
            .unwrap()
            .collect_all()
            .unwrap();

        let mut ms = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        ms.activate(key).unwrap();
        let serial = DGDataLoader::new(mapped_data.full(), BatchBy::Events(100), &mut ms)
            .unwrap()
            .collect_all()
            .unwrap();
        assert_identical(&reference, &serial);

        for workers in [2usize, 4] {
            let mut mp = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
            mp.activate(key).unwrap();
            let prefetched = PrefetchLoader::new(
                mapped_data.full(),
                BatchBy::Events(100),
                &mut mp,
                PrefetchConfig::default().with_workers(workers),
            )
            .unwrap()
            .collect_all()
            .unwrap();
            assert_identical(&reference, &prefetched);
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Tentpole (c) acceptance: concurrent ingest threads over one
/// group-committed tenant share fsyncs, every acknowledged chunk
/// survives a kill, and the recovered bytes match an in-memory replay.
#[test]
fn group_committed_concurrent_ingest_survives_recovery() {
    use tgm::graph::EdgeEvent;
    let dir = std::env::temp_dir().join(format!("tgm_it_groupingest_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let threads = 4usize;
    let per_thread = 200usize;
    {
        let mut router = TenantRouter::new();
        // No auto-seal while threads race: concurrently allocated
        // timestamps may append slightly out of order (legal within the
        // active segment), and a mid-race seal would turn the laggards
        // into stale appends. The recovered store seals instead.
        let handle = router
            .add_primary(
                "g",
                ServingConfig::primary(threads + 1, &dir)
                    .seal(SealPolicy::by_events(100_000))
                    .group_commit(),
            )
            .unwrap();
        // Each thread owns one source node and appends at a shared,
        // monotonically allocated timestamp, in chunks of 20.
        let clock = std::sync::atomic::AtomicI64::new(0);
        std::thread::scope(|scope| {
            for k in 0..threads {
                let handle = &handle;
                let clock = &clock;
                scope.spawn(move || {
                    for _ in 0..(per_thread / 20) {
                        let chunk: Vec<tgm::graph::Event> = (0..20)
                            .map(|_| {
                                let t = clock
                                    .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                                tgm::graph::Event::Edge(EdgeEvent {
                                    t,
                                    src: k as u32,
                                    dst: threads as u32,
                                    features: vec![t as f32],
                                })
                            })
                            .collect();
                        handle.ingest(chunk).unwrap();
                    }
                });
            }
        });
        assert_eq!(handle.total_edges(), threads * per_thread);
    } // kill: the router, handle and store drop; the lock releases

    let mut rec = persist::recover(
        SealPolicy::by_events(128),
        DurabilityPolicy {
            fsync_appends: true,
            group_commit: true,
            ..DurabilityPolicy::new(&dir)
        },
    )
    .unwrap();
    let snap = rec.snapshot().unwrap();
    assert_eq!(snap.num_edges(), threads * per_thread, "every barriered chunk survives");
    // Timestamps are exactly the allocated clock ticks, in order, and
    // each feature row matches its timestamp (no torn or crossed rows).
    let ts = snap.edge_ts();
    let expect: Vec<i64> = (0..(threads * per_thread) as i64).collect();
    assert_eq!(ts, expect);
    for i in 0..snap.num_edges() {
        assert_eq!(snap.edge_feat_row(i), &[ts[i] as f32][..], "row {i}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Regressions for the streaming-ingestion bugfix sweep, through the
/// public API: (a) node events count toward `SealPolicy::max_events`,
/// (b) node-event timestamps fold into the `max_span` tracker, (c)
/// edge-free pending node events hit a typed backpressure cap, (d) the
/// generator's year stepping is fallible rather than panicking.
#[test]
fn streaming_bugfix_sweep_regressions() {
    use tgm::graph::{EdgeEvent, NodeEvent};
    use tgm::TgmError;

    // (a) A node-event-heavy stream still seals at the size threshold.
    let mut st = SegmentedStorage::new(4, SealPolicy::by_events(3));
    st.append_edge(EdgeEvent { t: 0, src: 0, dst: 1, features: vec![] }).unwrap();
    assert!(!st.append_node_event(NodeEvent { t: 1, node: 0, features: vec![] }).unwrap());
    assert!(
        st.append_node_event(NodeEvent { t: 2, node: 1, features: vec![] }).unwrap(),
        "the third buffered event is a node event and must trip the seal"
    );
    assert_eq!(st.num_sealed_segments(), 1);

    // (b) A node event outside the edge span trips `max_span`.
    let mut st2 =
        SegmentedStorage::new(4, SealPolicy::by_events(usize::MAX).with_max_span(10));
    st2.append_edge(EdgeEvent { t: 0, src: 0, dst: 1, features: vec![] }).unwrap();
    assert!(st2.append_node_event(NodeEvent { t: 100, node: 0, features: vec![] }).unwrap());
    assert_eq!(st2.num_sealed_segments(), 1);

    // (c) Edge-free node events are bounded by a typed error, not OOM.
    let mut st3 =
        SegmentedStorage::new(4, SealPolicy::by_events(2).with_node_event_cap(2));
    st3.append_node_event(NodeEvent { t: 0, node: 0, features: vec![] }).unwrap();
    st3.append_node_event(NodeEvent { t: 1, node: 1, features: vec![] }).unwrap();
    let err = st3.append_node_event(NodeEvent { t: 2, node: 2, features: vec![] }).unwrap_err();
    assert!(matches!(err, TgmError::Backpressure(_)), "{err}");

    // (d) The yearly generator path builds through the fallible lookup.
    assert!(gen::by_name("trade", 0.2, 1).is_ok());
}

#[test]
fn discretization_pipeline_composes_with_loader() {
    let data = gen::by_name("reddit", 0.05, 2).unwrap();
    let hourly = discretize(data.storage(), TimeGranularity::Hour, ReduceOp::Count).unwrap();
    let utg = discretize_utg(data.storage(), TimeGranularity::Hour, ReduceOp::Count).unwrap();
    assert_eq!(hourly.num_edges(), utg.num_edges());
    // The discretized graph iterates by time at its own granularity.
    let d2 = DGData::new(hourly, "reddit-hourly", Task::LinkPrediction);
    let mut m = RecipeRegistry::build(tgm::hooks::RECIPE_SNAPSHOT).unwrap();
    m.activate("train").unwrap();
    let mut loader =
        DGDataLoader::new(d2.full(), BatchBy::Time(TimeGranularity::Day), &mut m).unwrap();
    let batches = loader.collect_all().unwrap();
    assert!(batches.len() > 5, "expect multiple daily snapshots");
    assert!(batches.iter().all(|b| b.has(tgm::hooks::attr::SNAPSHOT_ADJ)));
}

#[test]
fn edgebank_protocol_end_to_end() {
    let data = gen::by_name("wiki", 0.05, 3).unwrap();
    let splits = data.split().unwrap();
    let r = evaluate_edgebank(&data, &splits.test, EdgeBankMode::Unlimited, 10, 0).unwrap();
    let mrr = r.mrr.unwrap();
    assert!(mrr > 0.3, "EdgeBank beats random (1/(Q+1)~0.09) on repeats: {mrr}");
    assert!(mrr <= 1.0);
    assert_eq!(r.queries, splits.test.num_edges());
}

#[test]
fn train_eval_tpnet_end_to_end() {
    let Some(eng) = engine() else { return };
    let data = gen::by_name("wiki", 0.1, 4).unwrap();
    let mut pipe = Pipeline::new(&eng, data, PipelineConfig::new("tpnet_link")).unwrap();
    let r1 = pipe.train_epoch().unwrap();
    assert!(r1.mean_loss.is_finite() && r1.batches > 0);
    let r2 = pipe.train_epoch().unwrap();
    assert!(r2.mean_loss < r1.mean_loss, "loss should fall: {} -> {}", r1.mean_loss, r2.mean_loss);
    let val = pipe.evaluate(Split::Val).unwrap();
    let mrr = val.mrr.unwrap();
    assert!((0.0..=1.0).contains(&mrr) && val.queries > 0);
}

#[test]
fn dedup_and_naive_eval_agree_on_scores() {
    // The Table-9 optimization must be output-identical: only the data
    // path differs. TGN's memory is untouched by predict, but its update
    // runs during evaluate(), so compare naive first, fast second on a
    // stateless-eval model (graphmixer has no update artifact).
    let Some(eng) = engine() else { return };
    let data = gen::by_name("wiki", 0.08, 5).unwrap();
    let mut pipe = Pipeline::new(&eng, data, PipelineConfig::new("graphmixer_link")).unwrap();
    pipe.train_epoch().unwrap();
    let naive = pipe.evaluate_link_naive(Split::Val).unwrap();
    let fast = pipe.evaluate(Split::Val).unwrap();
    assert_eq!(fast.queries, naive.queries);
    assert!(
        (fast.mrr.unwrap() - naive.mrr.unwrap()).abs() < 1e-6,
        "dedup changed results: {} vs {}",
        fast.mrr.unwrap(),
        naive.mrr.unwrap()
    );
}

#[test]
fn snapshot_model_trains_on_time_iteration() {
    let Some(eng) = engine() else { return };
    let data = gen::by_name("wiki", 0.1, 6).unwrap();
    let mut cfg = PipelineConfig::new("tgcn_link");
    cfg.granularity = TimeGranularity::Day;
    let mut pipe = Pipeline::new(&eng, data, cfg).unwrap();
    let r = pipe.train_epoch().unwrap();
    assert!(r.mean_loss.is_finite() && r.batches > 5);
    let t = pipe.evaluate(Split::Test).unwrap();
    assert!(t.mrr.unwrap() > 0.0 && t.queries > 0);
}

#[test]
fn node_property_pipeline_runs() {
    let Some(eng) = engine() else { return };
    let data = gen::by_name("trade", 0.3, 7).unwrap();
    let mut cfg = PipelineConfig::new("gcn_node");
    cfg.granularity = TimeGranularity::Year;
    let mut pipe = Pipeline::new(&eng, data, cfg).unwrap();
    let r = pipe.train_epoch().unwrap();
    assert!(r.mean_loss.is_finite());
    let t = pipe.evaluate(Split::Test).unwrap();
    let ndcg = t.ndcg.unwrap();
    assert!((0.0..=1.0).contains(&ndcg), "{ndcg}");
}

#[test]
fn memory_model_state_persists_across_epochs() {
    let Some(eng) = engine() else { return };
    let data = gen::by_name("wiki", 0.05, 8).unwrap();
    let mut pipe = Pipeline::new(&eng, data, PipelineConfig::new("tgn_link")).unwrap();
    let s0 = pipe.runtime.state_to_host().unwrap();
    pipe.train_epoch().unwrap();
    let s1 = pipe.runtime.state_to_host().unwrap();
    assert_eq!(s0.len(), s1.len());
    assert!(s0.iter().zip(&s1).any(|(a, b)| a != b), "training must change state");
    pipe.runtime.reset_state().unwrap();
    let s2 = pipe.runtime.state_to_host().unwrap();
    assert_eq!(s0, s2, "reset restores the initial blob");
}

#[test]
fn oversized_dataset_rejected_by_profile() {
    let Some(eng) = engine() else { return };
    // dtdg512 profile caps N at 512; wiki at full scale has ~920 nodes.
    let data = gen::by_name("wiki", 1.0, 9).unwrap();
    let mut cfg = PipelineConfig::new("gcn_link");
    cfg.granularity = TimeGranularity::Day;
    assert!(Pipeline::new(&eng, data, cfg).is_err());
}

#[test]
fn checkpoint_round_trip() {
    let Some(eng) = engine() else { return };
    let data = gen::by_name("wiki", 0.05, 11).unwrap();
    let mut pipe = Pipeline::new(&eng, data, PipelineConfig::new("tpnet_link")).unwrap();
    pipe.train_epoch().unwrap();
    let trained = pipe.runtime.state_to_host().unwrap();

    let dir = std::env::temp_dir().join("tgm_ckpt_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tpnet.ckpt");
    tgm::runtime::checkpoint::save(&pipe.runtime, &path).unwrap();

    // Wipe state, restore, and verify bit-for-bit equality.
    pipe.runtime.reset_state().unwrap();
    assert_ne!(pipe.runtime.state_to_host().unwrap(), trained);
    tgm::runtime::checkpoint::load(&mut pipe.runtime, &path).unwrap();
    assert_eq!(pipe.runtime.state_to_host().unwrap(), trained);

    // Restoring into the wrong model fails loudly.
    let data2 = gen::by_name("wiki", 0.05, 11).unwrap();
    let mut other = Pipeline::new(&eng, data2, PipelineConfig::new("tgn_link")).unwrap();
    let err = tgm::runtime::checkpoint::load(&mut other.runtime, &path).unwrap_err();
    assert!(err.to_string().contains("tpnet_link"), "{err}");
}

/// Acceptance check for the DTDG materialized-view layer: under
/// randomized seal points, reduce ops, targets and tiered-compaction
/// installs, the incrementally maintained view is **byte-identical** to a
/// full-snapshot `discretize()` of everything sealed so far — edge and
/// node columns, f32 features compared bit-for-bit.
#[test]
fn dtdg_view_matches_full_discretize_under_random_seals_and_compaction() {
    use tgm::graph::{EdgeEvent, Event, NodeEvent};

    fn xorshift(s: &mut u64) -> u64 {
        *s ^= *s << 13;
        *s ^= *s >> 7;
        *s ^= *s << 17;
        *s
    }
    fn bits(f: &[f32]) -> Vec<u32> {
        f.iter().map(|x| x.to_bits()).collect()
    }

    let ops = [ReduceOp::Count, ReduceOp::Last, ReduceOp::Sum, ReduceOp::Mean, ReduceOp::Max];
    let targets = [TimeGranularity::Minute, TimeGranularity::Hour, TimeGranularity::Day];
    let mut s = 0x9E3779B97F4A7C15u64;
    let mut compactions = 0usize;

    for trial in 0..6u64 {
        let reduce = ops[(xorshift(&mut s) % ops.len() as u64) as usize];
        let target = targets[(xorshift(&mut s) % targets.len() as u64) as usize];
        let seal_every = 3 + (xorshift(&mut s) % 8) as usize;
        let fanout = 2 + (xorshift(&mut s) % 3) as usize;
        let num_nodes = 12u32;
        let mut store = SegmentedStorage::new(num_nodes as usize, SealPolicy::by_events(seal_every))
            .with_granularity(TimeGranularity::Second);
        let view = store.register_dtdg_view(target, reduce).unwrap();

        // Random stream: nondecreasing timestamps (ties included), a
        // negative-epoch origin on half the trials, ~1 in 5 events a node
        // event. Checkpoint every 150 events: seal, compact, compare.
        let mut t: i64 =
            if trial % 2 == 0 { -100_000 } else { 7 } + (xorshift(&mut s) % 1000) as i64;
        let n_events = 400 + (xorshift(&mut s) % 200) as usize;
        for i in 0..n_events {
            t += (xorshift(&mut s) % 900) as i64;
            let a = (xorshift(&mut s) % num_nodes as u64) as u32;
            let b = (xorshift(&mut s) % num_nodes as u64) as u32;
            let f = |r: u64| (r % 1000) as f32 * 0.25 - 100.0;
            if xorshift(&mut s) % 5 == 0 {
                store
                    .append(Event::Node(NodeEvent {
                        t,
                        node: a,
                        features: vec![f(xorshift(&mut s)), f(xorshift(&mut s))],
                    }))
                    .unwrap();
            } else {
                store
                    .append(Event::Edge(EdgeEvent {
                        t,
                        src: a,
                        dst: b,
                        features: vec![f(xorshift(&mut s)), f(xorshift(&mut s)), f(xorshift(&mut s))],
                    }))
                    .unwrap();
            }
            if i % 150 == 149 || i == n_events - 1 {
                store.seal().unwrap();
                if store.compact_tiered(fanout).unwrap().is_some() {
                    compactions += 1;
                    // A compaction install must not move the view: ids
                    // are never reused, so the affected run is the only
                    // thing that changed — and it changed byte-identically.
                    let gen_before = view.generation();
                    store.refresh_dtdg_views();
                    assert_eq!(view.generation(), gen_before, "install forced a view rebuild");
                }
                let want = discretize(&store.snapshot().unwrap(), target, reduce).unwrap();
                let got = view.pin().expect("view published after first sealed edge").coalesce();
                let ctx = format!("trial {trial} event {i} reduce {reduce:?} target {target:?}");
                assert_eq!(got.edge_ts(), want.edge_ts(), "{ctx}");
                assert_eq!(got.edge_src(), want.edge_src(), "{ctx}");
                assert_eq!(got.edge_dst(), want.edge_dst(), "{ctx}");
                assert_eq!(got.edge_feat_dim(), want.edge_feat_dim(), "{ctx}");
                assert_eq!(bits(got.edge_feats()), bits(want.edge_feats()), "{ctx}");
                assert_eq!(got.node_event_ts(), want.node_event_ts(), "{ctx}");
                assert_eq!(got.node_event_ids(), want.node_event_ids(), "{ctx}");
                assert_eq!(got.node_feat_dim(), want.node_feat_dim(), "{ctx}");
                assert_eq!(bits(got.node_event_feats()), bits(want.node_event_feats()), "{ctx}");
                assert_eq!(got.num_nodes(), want.num_nodes(), "{ctx}");
            }
        }
    }
    assert!(compactions > 0, "the property never exercised a tiered-compaction install");
}

#[test]
fn time_chunked_eval_matches_batch_count() {
    // RQ3 machinery: oversized time buckets split into profile-sized
    // chunks without losing events.
    let Some(eng) = engine() else { return };
    let data = gen::by_name("wiki", 0.1, 12).unwrap();
    let mut pipe = Pipeline::new(&eng, data, PipelineConfig::new("tpnet_link")).unwrap();
    pipe.train_epoch().unwrap();
    let by_events = pipe.evaluate_link_with(Split::Test, BatchBy::Events(200)).unwrap();
    let by_day = pipe
        .evaluate_link_with(Split::Test, BatchBy::Time(TimeGranularity::Day))
        .unwrap();
    assert_eq!(by_events.queries, by_day.queries, "every test edge scored once");
    assert!(by_day.mrr.unwrap() > 0.0);
}

/// Replicated-serving tentpole, part 1: a tailing replica killed at
/// arbitrary points (mid-WAL, mid-segment-ship, whatever its cursor
/// happened to be) restarts over its local cache, revalidates instead of
/// re-shipping, catches back up, and ends byte-identical to the primary
/// — hooked batches included, serial and prefetch at >= 2 workers.
#[test]
fn replica_killed_at_arbitrary_points_catches_up_without_reshipping() {
    let data = gen::by_name("wiki", 0.05, 61).unwrap();
    let base = std::env::temp_dir().join(format!("tgm_it_replkill_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let pdir = base.join("primary");
    let rdir = base.join("replica");
    let mut primary =
        SegmentedStorage::new(data.storage().num_nodes(), SealPolicy::by_events(97))
            .with_granularity(data.storage().granularity())
            .with_durability(DurabilityPolicy::new(&pdir))
            .unwrap();
    let mut source = ReplaySource::from_data(&data);
    let events = source.next_chunk(usize::MAX);
    let log = Arc::new(DirTransport::new(&pdir));

    // Seed a quarter of the stream so the first bootstrap ships real
    // segment files from a primary that keeps its directory locked.
    let seed = events.len() / 4;
    for ev in &events[..seed] {
        primary.append(ev.clone()).unwrap();
    }
    let (mut replica, first) =
        Replica::bootstrap("kill-r", Arc::clone(&log), ReplicaConfig::new(&rdir)).unwrap();
    assert!(first.shipped_bytes > 0, "the first bootstrap must fetch the seed segments");
    assert_eq!(first.reused_segments, 0, "a fresh replica dir has nothing to revalidate");

    // Stream the rest in randomized chunks, polling at a randomized
    // cadence so the replica's WAL cursor sits at arbitrary offsets —
    // then kill it at random points and restart over the same dir.
    let mut rng = tgm::util::Rng::new(6161);
    let mut restarts = 0usize;
    let mut i = seed;
    while i < events.len() {
        let end = (i + rng.range(1, 400) as usize).min(events.len());
        for ev in &events[i..end] {
            primary.append(ev.clone()).unwrap();
        }
        i = end;
        if rng.range(0, 100) < 60 {
            replica.poll().unwrap();
        }
        if rng.range(0, 100) < 25 || (i == events.len() && restarts == 0) {
            let cached = replica.num_sealed_segments();
            drop(replica); // kill: releases the replica dir lock, keeps the cache
            let (r, again) =
                Replica::bootstrap("kill-r", Arc::clone(&log), ReplicaConfig::new(&rdir))
                    .unwrap();
            replica = r;
            restarts += 1;
            assert_eq!(
                again.reused_segments, cached,
                "restart {restarts}: every cached segment must be revalidated, not re-shipped"
            );
            assert!(again.segments >= cached, "the sealed stack never shrinks without compaction");
        }
    }
    assert!(restarts > 0);

    // Converge, then compare: snapshot bytes, then hooked batches.
    let outcome = replica.poll().unwrap();
    assert!(outcome.published, "a serial poll with no seal race must catch up");
    let psnap = primary.snapshot().unwrap();
    let rsnap = replica.pin().unwrap();
    assert_eq!(replica.applied_generation(), psnap.generation());
    assert_eq!(rsnap.edge_ts(), psnap.edge_ts());
    assert_eq!(rsnap.edge_src(), psnap.edge_src());
    assert_eq!(rsnap.edge_dst(), psnap.edge_dst());
    assert_eq!(rsnap.edge_feats(), psnap.edge_feats());
    assert_eq!(rsnap.num_node_events(), psnap.num_node_events());

    let pdata = DGData::from_snapshot(psnap, "primary", Task::LinkPrediction);
    let rdata = DGData::from_snapshot(rsnap, "replica", Task::LinkPrediction);
    for key in ["train", "val"] {
        let mut mh = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        mh.activate(key).unwrap();
        let reference = DGDataLoader::new(pdata.full(), BatchBy::Events(100), &mut mh)
            .unwrap()
            .collect_all()
            .unwrap();
        assert!(reference.len() > 2);

        let mut ms = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
        ms.activate(key).unwrap();
        let serial = DGDataLoader::new(rdata.full(), BatchBy::Events(100), &mut ms)
            .unwrap()
            .collect_all()
            .unwrap();
        assert_identical(&reference, &serial);

        for workers in [2usize, 4] {
            let mut mp = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
            mp.activate(key).unwrap();
            let prefetched = PrefetchLoader::new(
                rdata.full(),
                BatchBy::Events(100),
                &mut mp,
                PrefetchConfig::default().with_workers(workers),
            )
            .unwrap()
            .collect_all()
            .unwrap();
            assert_identical(&reference, &prefetched);
        }
    }
    drop(replica);
    drop(primary);
    let _ = std::fs::remove_dir_all(&base);
}

/// Replicated-serving tentpole, part 2: primary-side tiered compaction
/// reaches the replica as run-replacement deltas — a handful of
/// installed segments, never a resync, never a wholesale re-ship — and a
/// post-compaction restart ships zero bytes because everything current
/// is already cached locally.
#[test]
fn replica_ships_compaction_as_deltas_and_restarts_from_cache() {
    let data = gen::by_name("wiki", 0.05, 63).unwrap();
    let base = std::env::temp_dir().join(format!("tgm_it_repldelta_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let pdir = base.join("primary");
    let rdir = base.join("replica");
    let mut primary =
        SegmentedStorage::new(data.storage().num_nodes(), SealPolicy::by_events(97))
            .with_granularity(data.storage().granularity())
            .with_durability(DurabilityPolicy::new(&pdir))
            .unwrap();
    let mut source = ReplaySource::from_data(&data);
    for ev in source.next_chunk(usize::MAX) {
        primary.append(ev).unwrap();
    }
    primary.seal().unwrap();

    let log = Arc::new(DirTransport::new(&pdir));
    let (mut replica, first) =
        Replica::bootstrap("delta-r", Arc::clone(&log), ReplicaConfig::new(&rdir)).unwrap();
    let pre_segments = replica.num_sealed_segments();
    assert!(pre_segments > 8, "want a tall sealed stack, got {pre_segments}");
    assert_eq!(first.segments, pre_segments);

    // Tiered compaction to its fixpoint on the primary, then let the
    // replica reconcile. Installs must be the new merged runs only.
    while primary.compact_tiered(3).unwrap().is_some() {}
    let shipped_before = replica.shipped_bytes();
    let mut installed = 0usize;
    for round in 0.. {
        assert!(round < 10, "replica never converged on the compacted stack");
        let outcome = replica.poll().unwrap();
        assert!(!outcome.resynced, "serial compaction must arrive as deltas, not a resync");
        installed += outcome.installed_segments;
        if outcome.published && replica.num_sealed_segments() < pre_segments {
            break;
        }
    }
    assert!(installed > 0, "compaction must install replacement runs");
    assert!(
        installed < pre_segments,
        "{installed} installs for a {pre_segments}-segment stack is a re-ship, not a delta"
    );
    assert!(replica.shipped_bytes() > shipped_before, "new runs are fetched, not conjured");

    let psnap = primary.snapshot().unwrap();
    let rsnap = replica.pin().unwrap();
    assert_eq!(replica.applied_generation(), psnap.generation());
    assert_eq!(rsnap.edge_ts(), psnap.edge_ts());
    assert_eq!(rsnap.edge_feats(), psnap.edge_feats());

    // Restart over the same cache: the current stack is fully local, so
    // nothing ships — the zero-re-ship invariant across restarts.
    drop(replica);
    let (replica2, again) =
        Replica::bootstrap("delta-r2", Arc::clone(&log), ReplicaConfig::new(&rdir)).unwrap();
    assert_eq!(again.shipped_bytes, 0, "a fully cached restart must ship zero bytes");
    assert_eq!(again.reused_segments, again.segments);
    assert_eq!(replica2.pin().unwrap().edge_ts(), psnap.edge_ts());
    drop(replica2);
    drop(primary);
    let _ = std::fs::remove_dir_all(&base);
}

/// Bugfix regression: registering a tenant over a directory whose WAL
/// tail was torn mid-record must surface the recovery diagnostics
/// through the serving tier (`TenantHandle::recovery_report`) instead of
/// swallowing them — and still serve the acknowledged prefix through the
/// unified read-handle API.
#[test]
fn torn_tail_recovery_report_surfaces_through_the_serving_tier() {
    let data = gen::by_name("wiki", 0.05, 62).unwrap();
    let dir = std::env::temp_dir().join(format!("tgm_it_tornreport_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    {
        let mut st =
            SegmentedStorage::new(data.storage().num_nodes(), SealPolicy::by_events(97))
                .with_granularity(data.storage().granularity())
                .with_durability(DurabilityPolicy::new(&dir))
                .unwrap();
        let mut source = ReplaySource::from_data(&data);
        for ev in source.next_chunk(500) {
            st.append(ev).unwrap();
        }
        assert!(st.pending_edges() + st.pending_node_events() > 0, "want a live WAL tail");
    } // crash
    // Tear the tail mid-record: the last acknowledged append loses its
    // final bytes, as if the disk absorbed a partial sector.
    let wal_path = dir.join("wal.log");
    let wal = std::fs::read(&wal_path).unwrap();
    std::fs::write(&wal_path, &wal[..wal.len() - 3]).unwrap();

    let mut router = TenantRouter::new();
    let id = TenantId::from("wiki");
    let handle = router
        .add_primary(
            id.clone(),
            ServingConfig::primary(data.storage().num_nodes(), &dir)
                .seal(SealPolicy::by_events(97)),
        )
        .unwrap();
    let report = handle
        .recovery_report()
        .expect("recovery over an existing directory must carry a report");
    assert!(report.torn_tail, "the torn record must be diagnosed, not silently dropped");
    assert!(report.dropped_bytes > 0);
    assert!(report.sealed_segments > 0);
    assert!(report.replayed_events > 0, "the complete-record prefix of the tail survives");
    assert!(!report.stale_wal_discarded);

    // The tenant still serves the acknowledged prefix, and the unified
    // read-handle API resolves to it.
    let h = router.read_handle(&id).unwrap();
    let snap = h.pin().unwrap();
    assert!(snap.num_edges() > 0);
    assert_eq!(
        snap.num_edges() + snap.num_node_events(),
        report.sealed_segments * 97 + report.replayed_events,
        "recovered prefix = sealed segments + surviving WAL records"
    );
    drop(router);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn csv_round_trip_feeds_pipeline() {
    let dir = std::env::temp_dir().join("tgm_integration_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("g.csv");
    let data = gen::by_name("wiki", 0.05, 10).unwrap();
    tgm::io::to_csv(&data, &path).unwrap();
    let loaded = tgm::io::from_csv(&path, "wiki-csv", Task::LinkPrediction).unwrap();
    assert_eq!(loaded.data.storage().num_edges(), data.storage().num_edges());
    // Loaded data splits and iterates.
    let splits = loaded.data.split().unwrap();
    assert!(splits.train.num_edges() > 0);
}
