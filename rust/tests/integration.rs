//! Integration tests across storage + hooks + loader + runtime +
//! coordinator. Tests needing compiled artifacts skip gracefully when
//! `make artifacts` hasn't run (CI without the Python toolchain).

use tgm::coordinator::{evaluate_edgebank, Pipeline, PipelineConfig, Split};
use tgm::graph::{
    discretize, discretize_utg, DGData, ReduceOp, SealPolicy, SegmentedStorage, Task,
};
use tgm::hooks::recipes::{RecipeRegistry, RECIPE_TGB_LINK};
use tgm::hooks::MaterializedBatch;
use tgm::io::gen;
use tgm::io::stream::{EventSource, ReplaySource};
use tgm::loader::{BatchBy, DGDataLoader, PrefetchConfig, PrefetchLoader};
use tgm::models::EdgeBankMode;
use tgm::runtime::XlaEngine;
use tgm::util::TimeGranularity;

fn engine() -> Option<XlaEngine> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    XlaEngine::cpu(dir).ok()
}

#[test]
fn full_data_path_without_runtime() {
    // storage -> splits -> hooks -> loader over a surrogate dataset.
    let data = gen::by_name("wiki", 0.05, 1).unwrap();
    let splits = data.split().unwrap();
    let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
    m.activate("train").unwrap();
    let mut loader = DGDataLoader::new(splits.train.clone(), BatchBy::Events(100), &mut m).unwrap();
    let batches = loader.collect_all().unwrap();
    assert!(!batches.is_empty());
    let total: usize = batches.iter().map(|b| b.num_edges()).sum();
    assert_eq!(total, splits.train.num_edges());
    for b in &batches {
        assert!(b.has(tgm::hooks::attr::NEGATIVES));
        assert!(b.has(tgm::hooks::attr::NEIGHBORS));
    }
}

/// Acceptance check for the prefetch pipeline: byte-identical
/// `MaterializedBatch` contents vs the serial loader, for both event and
/// time iteration, with >= 2 workers, through the public API.
#[test]
fn prefetch_loader_is_deterministic_end_to_end() {
    fn identical(a: &[MaterializedBatch], b: &[MaterializedBatch]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!((x.start, x.end), (y.start, y.end));
            assert_eq!(x.src, y.src);
            assert_eq!(x.dst, y.dst);
            assert_eq!(x.ts, y.ts);
            assert_eq!(x.edge_indices, y.edge_indices);
            assert_eq!(x.attr_names(), y.attr_names());
            for name in x.attr_names() {
                assert_eq!(x.get(name).unwrap(), y.get(name).unwrap(), "attr `{name}`");
            }
        }
    }

    let data = gen::by_name("wiki", 0.05, 21).unwrap();
    for by in [BatchBy::Events(100), BatchBy::Time(TimeGranularity::Day)] {
        for key in ["train", "val"] {
            let mut ms = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
            ms.activate(key).unwrap();
            let serial = DGDataLoader::new(data.full(), by, &mut ms)
                .unwrap()
                .with_event_cap(150)
                .collect_all()
                .unwrap();
            assert!(serial.len() > 2, "{by:?}/{key}: want several batches");

            let mut mp = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
            mp.activate(key).unwrap();
            let prefetched = PrefetchLoader::new(
                data.full(),
                by,
                &mut mp,
                PrefetchConfig::default().with_workers(3).with_event_cap(150),
            )
            .unwrap()
            .collect_all()
            .unwrap();
            identical(&serial, &prefetched);
        }
    }
}

fn assert_identical(a: &[MaterializedBatch], b: &[MaterializedBatch]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!((x.start, x.end), (y.start, y.end));
        assert_eq!(x.src, y.src);
        assert_eq!(x.dst, y.dst);
        assert_eq!(x.ts, y.ts);
        assert_eq!(x.edge_indices, y.edge_indices);
        assert_eq!(x.node_events, y.node_events);
        assert_eq!(x.attr_names(), y.attr_names());
        for name in x.attr_names() {
            assert_eq!(x.get(name).unwrap(), y.get(name).unwrap(), "attr `{name}`");
        }
    }
}

/// Replay a dataset's event log through a segmented store (many small
/// sealed segments) and return it as a dataset over the final snapshot.
fn streamed_copy(data: &DGData, seal_every: usize) -> DGData {
    let mut store = SegmentedStorage::new(
        data.storage().num_nodes(),
        SealPolicy { max_events: seal_every, max_span: None },
    )
    .with_granularity(data.storage().granularity());
    let mut source = ReplaySource::from_data(data);
    loop {
        let chunk = source.next_chunk(777);
        if chunk.is_empty() {
            break;
        }
        for ev in chunk {
            store.append(ev).unwrap();
        }
    }
    store.seal().unwrap();
    DGData::from_snapshot(store.snapshot().unwrap(), data.name(), data.task())
}

/// Acceptance criterion for the segmented-storage refactor: a training
/// run over a snapshot of a fully appended-then-sealed stream produces
/// byte-identical batches — event and time iteration, serial and prefetch
/// at >= 2 workers — to the same data built via `GraphStorage::from_events`.
#[test]
fn streamed_snapshot_matches_from_events_serial_and_prefetch() {
    let one_shot = gen::by_name("wiki", 0.05, 33).unwrap();
    let streamed = streamed_copy(&one_shot, 97);
    assert!(
        streamed.storage().num_segments() > 4,
        "want a genuinely multi-segment snapshot, got {}",
        streamed.storage().num_segments()
    );

    for by in [BatchBy::Events(100), BatchBy::Time(TimeGranularity::Day)] {
        for key in ["train", "val"] {
            let mut ms = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
            ms.activate(key).unwrap();
            let reference = DGDataLoader::new(one_shot.full(), by, &mut ms)
                .unwrap()
                .with_event_cap(150)
                .collect_all()
                .unwrap();
            assert!(reference.len() > 2, "{by:?}/{key}: want several batches");

            // Serial loader over the streamed snapshot.
            let mut mt = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
            mt.activate(key).unwrap();
            let serial = DGDataLoader::new(streamed.full(), by, &mut mt)
                .unwrap()
                .with_event_cap(150)
                .collect_all()
                .unwrap();
            assert_identical(&reference, &serial);

            // Prefetch loader over the streamed snapshot at >= 2 workers.
            for workers in [2usize, 4] {
                let mut mp = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
                mp.activate(key).unwrap();
                let prefetched = PrefetchLoader::new(
                    streamed.full(),
                    by,
                    &mut mp,
                    PrefetchConfig::default().with_workers(workers).with_event_cap(150),
                )
                .unwrap()
                .collect_all()
                .unwrap();
                assert_identical(&reference, &prefetched);
            }
        }
    }
}

/// Node events stream through segments too (genre carries them), and the
/// materialized `node_events` column survives the logical-offset layer.
#[test]
fn streamed_node_events_match_one_shot() {
    let one_shot = gen::by_name("genre", 0.03, 7).unwrap();
    assert!(one_shot.storage().num_node_events() > 0);
    let streamed = streamed_copy(&one_shot, 211);
    assert_eq!(
        streamed.storage().num_node_events(),
        one_shot.storage().num_node_events()
    );

    let mut m1 = RecipeRegistry::build(tgm::hooks::RECIPE_TGB_NODE).unwrap();
    m1.activate("train").unwrap();
    let a = DGDataLoader::new(one_shot.full(), BatchBy::Events(128), &mut m1)
        .unwrap()
        .collect_all()
        .unwrap();
    let mut m2 = RecipeRegistry::build(tgm::hooks::RECIPE_TGB_NODE).unwrap();
    m2.activate("train").unwrap();
    let b = DGDataLoader::new(streamed.full(), BatchBy::Events(128), &mut m2)
        .unwrap()
        .collect_all()
        .unwrap();
    assert_identical(&a, &b);
}

#[test]
fn discretization_pipeline_composes_with_loader() {
    let data = gen::by_name("reddit", 0.05, 2).unwrap();
    let hourly = discretize(data.storage(), TimeGranularity::Hour, ReduceOp::Count).unwrap();
    let utg = discretize_utg(data.storage(), TimeGranularity::Hour, ReduceOp::Count).unwrap();
    assert_eq!(hourly.num_edges(), utg.num_edges());
    // The discretized graph iterates by time at its own granularity.
    let d2 = DGData::new(hourly, "reddit-hourly", Task::LinkPrediction);
    let mut m = RecipeRegistry::build(tgm::hooks::RECIPE_SNAPSHOT).unwrap();
    m.activate("train").unwrap();
    let mut loader =
        DGDataLoader::new(d2.full(), BatchBy::Time(TimeGranularity::Day), &mut m).unwrap();
    let batches = loader.collect_all().unwrap();
    assert!(batches.len() > 5, "expect multiple daily snapshots");
    assert!(batches.iter().all(|b| b.has(tgm::hooks::attr::SNAPSHOT_ADJ)));
}

#[test]
fn edgebank_protocol_end_to_end() {
    let data = gen::by_name("wiki", 0.05, 3).unwrap();
    let splits = data.split().unwrap();
    let r = evaluate_edgebank(&data, &splits.test, EdgeBankMode::Unlimited, 10, 0).unwrap();
    let mrr = r.mrr.unwrap();
    assert!(mrr > 0.3, "EdgeBank beats random (1/(Q+1)~0.09) on repeats: {mrr}");
    assert!(mrr <= 1.0);
    assert_eq!(r.queries, splits.test.num_edges());
}

#[test]
fn train_eval_tpnet_end_to_end() {
    let Some(eng) = engine() else { return };
    let data = gen::by_name("wiki", 0.1, 4).unwrap();
    let mut pipe = Pipeline::new(&eng, data, PipelineConfig::new("tpnet_link")).unwrap();
    let r1 = pipe.train_epoch().unwrap();
    assert!(r1.mean_loss.is_finite() && r1.batches > 0);
    let r2 = pipe.train_epoch().unwrap();
    assert!(r2.mean_loss < r1.mean_loss, "loss should fall: {} -> {}", r1.mean_loss, r2.mean_loss);
    let val = pipe.evaluate(Split::Val).unwrap();
    let mrr = val.mrr.unwrap();
    assert!((0.0..=1.0).contains(&mrr) && val.queries > 0);
}

#[test]
fn dedup_and_naive_eval_agree_on_scores() {
    // The Table-9 optimization must be output-identical: only the data
    // path differs. TGN's memory is untouched by predict, but its update
    // runs during evaluate(), so compare naive first, fast second on a
    // stateless-eval model (graphmixer has no update artifact).
    let Some(eng) = engine() else { return };
    let data = gen::by_name("wiki", 0.08, 5).unwrap();
    let mut pipe = Pipeline::new(&eng, data, PipelineConfig::new("graphmixer_link")).unwrap();
    pipe.train_epoch().unwrap();
    let naive = pipe.evaluate_link_naive(Split::Val).unwrap();
    let fast = pipe.evaluate(Split::Val).unwrap();
    assert_eq!(fast.queries, naive.queries);
    assert!(
        (fast.mrr.unwrap() - naive.mrr.unwrap()).abs() < 1e-6,
        "dedup changed results: {} vs {}",
        fast.mrr.unwrap(),
        naive.mrr.unwrap()
    );
}

#[test]
fn snapshot_model_trains_on_time_iteration() {
    let Some(eng) = engine() else { return };
    let data = gen::by_name("wiki", 0.1, 6).unwrap();
    let mut cfg = PipelineConfig::new("tgcn_link");
    cfg.granularity = TimeGranularity::Day;
    let mut pipe = Pipeline::new(&eng, data, cfg).unwrap();
    let r = pipe.train_epoch().unwrap();
    assert!(r.mean_loss.is_finite() && r.batches > 5);
    let t = pipe.evaluate(Split::Test).unwrap();
    assert!(t.mrr.unwrap() > 0.0 && t.queries > 0);
}

#[test]
fn node_property_pipeline_runs() {
    let Some(eng) = engine() else { return };
    let data = gen::by_name("trade", 0.3, 7).unwrap();
    let mut cfg = PipelineConfig::new("gcn_node");
    cfg.granularity = TimeGranularity::Year;
    let mut pipe = Pipeline::new(&eng, data, cfg).unwrap();
    let r = pipe.train_epoch().unwrap();
    assert!(r.mean_loss.is_finite());
    let t = pipe.evaluate(Split::Test).unwrap();
    let ndcg = t.ndcg.unwrap();
    assert!((0.0..=1.0).contains(&ndcg), "{ndcg}");
}

#[test]
fn memory_model_state_persists_across_epochs() {
    let Some(eng) = engine() else { return };
    let data = gen::by_name("wiki", 0.05, 8).unwrap();
    let mut pipe = Pipeline::new(&eng, data, PipelineConfig::new("tgn_link")).unwrap();
    let s0 = pipe.runtime.state_to_host().unwrap();
    pipe.train_epoch().unwrap();
    let s1 = pipe.runtime.state_to_host().unwrap();
    assert_eq!(s0.len(), s1.len());
    assert!(s0.iter().zip(&s1).any(|(a, b)| a != b), "training must change state");
    pipe.runtime.reset_state().unwrap();
    let s2 = pipe.runtime.state_to_host().unwrap();
    assert_eq!(s0, s2, "reset restores the initial blob");
}

#[test]
fn oversized_dataset_rejected_by_profile() {
    let Some(eng) = engine() else { return };
    // dtdg512 profile caps N at 512; wiki at full scale has ~920 nodes.
    let data = gen::by_name("wiki", 1.0, 9).unwrap();
    let mut cfg = PipelineConfig::new("gcn_link");
    cfg.granularity = TimeGranularity::Day;
    assert!(Pipeline::new(&eng, data, cfg).is_err());
}

#[test]
fn checkpoint_round_trip() {
    let Some(eng) = engine() else { return };
    let data = gen::by_name("wiki", 0.05, 11).unwrap();
    let mut pipe = Pipeline::new(&eng, data, PipelineConfig::new("tpnet_link")).unwrap();
    pipe.train_epoch().unwrap();
    let trained = pipe.runtime.state_to_host().unwrap();

    let dir = std::env::temp_dir().join("tgm_ckpt_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tpnet.ckpt");
    tgm::runtime::checkpoint::save(&pipe.runtime, &path).unwrap();

    // Wipe state, restore, and verify bit-for-bit equality.
    pipe.runtime.reset_state().unwrap();
    assert_ne!(pipe.runtime.state_to_host().unwrap(), trained);
    tgm::runtime::checkpoint::load(&mut pipe.runtime, &path).unwrap();
    assert_eq!(pipe.runtime.state_to_host().unwrap(), trained);

    // Restoring into the wrong model fails loudly.
    let data2 = gen::by_name("wiki", 0.05, 11).unwrap();
    let mut other = Pipeline::new(&eng, data2, PipelineConfig::new("tgn_link")).unwrap();
    let err = tgm::runtime::checkpoint::load(&mut other.runtime, &path).unwrap_err();
    assert!(err.to_string().contains("tpnet_link"), "{err}");
}

#[test]
fn time_chunked_eval_matches_batch_count() {
    // RQ3 machinery: oversized time buckets split into profile-sized
    // chunks without losing events.
    let Some(eng) = engine() else { return };
    let data = gen::by_name("wiki", 0.1, 12).unwrap();
    let mut pipe = Pipeline::new(&eng, data, PipelineConfig::new("tpnet_link")).unwrap();
    pipe.train_epoch().unwrap();
    let by_events = pipe.evaluate_link_with(Split::Test, BatchBy::Events(200)).unwrap();
    let by_day = pipe
        .evaluate_link_with(Split::Test, BatchBy::Time(TimeGranularity::Day))
        .unwrap();
    assert_eq!(by_events.queries, by_day.queries, "every test edge scored once");
    assert!(by_day.mrr.unwrap() > 0.0);
}

#[test]
fn csv_round_trip_feeds_pipeline() {
    let dir = std::env::temp_dir().join("tgm_integration_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("g.csv");
    let data = gen::by_name("wiki", 0.05, 10).unwrap();
    tgm::io::to_csv(&data, &path).unwrap();
    let loaded = tgm::io::from_csv(&path, "wiki-csv", Task::LinkPrediction).unwrap();
    assert_eq!(loaded.data.storage().num_edges(), data.storage().num_edges());
    // Loaded data splits and iterates.
    let splits = loaded.data.split().unwrap();
    assert!(splits.train.num_edges() > 0);
}
