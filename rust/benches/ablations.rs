//! Ablations for the design choices DESIGN.md calls out:
//!
//! 1. Sampler microbench (no model): circular-buffer recency vs uniform
//!    (CSR) vs DyGLib-style naive history copies — isolates the §5.1
//!    claim that the vectorized recency sampler drives performance.
//! 2. Discretization reduction operators: cost of Sum/Mean/Last/Max vs
//!    Count under the vectorized path.
//! 3. Cached timestamp index: storage `edge_range` via the unique-ts
//!    index vs a full binary search over the raw event array.
//! 4. Device-boundary packing: bulk byte view vs per-element copies.
//! 5. Serial vs prefetch batch materialization at varying worker counts
//!    (the parallel pipeline's end-to-end win on the data path).
//! 6. Streaming ingestion: append+seal+snapshot throughput vs one-shot
//!    `from_events`, and batch-materialization latency on a multi-segment
//!    snapshot vs the compacted single-segment baseline (the
//!    logical-offset layer's read overhead; target < 15%).
//! 7. Sharded multi-tenant serving: shared pool vs dedicated loaders.
//! 8. Durable segment store: WAL overhead, recovery vs segment count,
//!    tiered-vs-full compaction write amplification at 16/64 sealed
//!    segments, and per-append fsync vs group-commit throughput.
//! 9. SIMD kernels: masked feature-row gather throughput (GB/s) and
//!    timestamp filtered counts, selected backend vs the scalar
//!    reference (`TGM_KERNELS=scalar` forces the fallback).
//!
//! 10. DTDG materialized views: per-seal incremental refresh vs
//!     rescanning the full snapshot after every seal at 4/16/64 seals,
//!     and the vectorized one-shot discretization vs the UTG baseline.
//! 11. Point-query serving latency: p50/p99 of the zero-materialization
//!     point path on a shared pool under mixed point-query + batch-scan
//!     + ingest load, vs answering the same question through a
//!     one-batch pooled stream (target: >= 10x lower p99).
//! 12. Observability overhead: the same durable-ingest loop with the
//!     process-global metrics registry recording vs disabled — the
//!     per-append counter increments and per-seal histogram records
//!     must cost <= 3% of ingest throughput.
//! 13. Replicated serving: replica bootstrap throughput vs the
//!     primary's sealed-segment count (1/4/16 — copy + open + catch-up,
//!     no primary lock taken), and aggregate point-query QPS served
//!     entirely by 1/2/4 WAL-tailing replicas behind the unified
//!     read-handle API.
//!
//! `TGM_ABLATION=streaming,sharded,persist` runs a comma-selected
//! subset (CI's bench-regression job does exactly that); unset runs
//! everything. Rows tagged `BENCH_METRIC` feed `scripts/bench_gate.py`.

#[path = "common.rs"]
mod common;

use tgm::graph::{
    discretize, discretize_utg, GraphStorage, ReduceOp, SealPolicy, SegmentedStorage,
    StorageSnapshot,
};
use tgm::hooks::batch::attr;
use tgm::hooks::hook::{Hook, StatelessHook};
use tgm::hooks::{
    HookContext, MaterializedBatch, NaiveSampler, RecencySampler, RecipeRegistry, SamplerConfig,
    UniformSampler, RECIPE_TGB_LINK,
};
use tgm::io::gen;
use tgm::loader::{plan_batches, BatchBy, DGDataLoader, PrefetchConfig, PrefetchLoader};
use tgm::persist::{DurabilityPolicy, SegmentBacking};
use tgm::util::{Tensor, TimeGranularity};

fn batches_of(storage: &StorageSnapshot, bsz: usize) -> Vec<MaterializedBatch> {
    let n = storage.num_edges();
    let mut out = Vec::new();
    let mut lo = 0;
    while lo < n {
        let hi = (lo + bsz).min(n);
        let mut b =
            MaterializedBatch::new(storage.edge_ts_at(lo), storage.edge_ts_at(hi - 1) + 1);
        for i in lo..hi {
            b.src.push(storage.edge_src_at(i));
            b.dst.push(storage.edge_dst_at(i));
            b.ts.push(storage.edge_ts_at(i));
            b.edge_indices.push(i as u32);
        }
        b.set(attr::EDGE_FEATS, Tensor::zeros_f32(&[hi - lo, storage.edge_feat_dim()]));
        out.push(b);
        lo = hi;
    }
    out
}

fn main() {
    let scale = common::bench_scale();
    let sampler_on = common::section_enabled("sampler");
    let reduce_on = common::section_enabled("reduce");
    let ts_index_on = common::section_enabled("ts_index");
    let literal_on = common::section_enabled("literal");
    let prefetch_on = common::section_enabled("prefetch");
    let streaming_on = common::section_enabled("streaming");
    let sharded_on = common::section_enabled("sharded");
    let persist_on = common::section_enabled("persist");
    let kernels_on = common::section_enabled("kernels");
    let discretize_on = common::section_enabled("discretize");
    let latency_on = common::section_enabled("latency");
    let obs_on = common::section_enabled("obs");
    let replica_on = common::section_enabled("replica");

    // 9. SIMD kernel microbench (`ablation.kernels`): raw primitive
    //    throughput under whichever backend the runtime dispatch picked,
    //    next to the scalar reference the property tests pin it against.
    if kernels_on {
        use tgm::kernels;
        let rows = 200_000usize;
        let dim = 16usize;
        let feats: Vec<f32> = (0..rows * dim).map(|i| (i % 97) as f32).collect();
        let n = 50_000usize;
        let mut state = 0x2545F4914F6CDD1Du64;
        let eidx: Vec<u32> = (0..n)
            .map(|_| {
                state ^= state << 13;
                state ^= state >> 7;
                state ^= state << 17;
                (state % rows as u64) as u32
            })
            .collect();
        let mask: Vec<f32> = (0..n).map(|i| if i % 8 == 7 { 0.0 } else { 1.0 }).collect();
        let mut out = vec![0.0f32; n * dim];
        // Bytes actually moved per pass: read + write of unmasked rows.
        let live_rows = mask.iter().filter(|&&m| m > 0.0).count();
        let bytes_per_pass = (2 * live_rows * dim * 4) as f64;
        let fast = common::time_runs(3, 10, || {
            kernels::gather_rows_masked_f32(&feats, dim, &eidx, &mask, &mut out);
            out[0]
        });
        let slow = common::time_runs(3, 10, || {
            kernels::gather_rows_masked_f32_scalar(&feats, dim, &eidx, &mask, &mut out);
            out[0]
        });
        let gbps = bytes_per_pass / common::mean(&fast).max(1e-12) / 1e9;
        common::report(
            "ablation.kernels",
            &format!("row gather, {} backend", kernels::backend()),
            &fast,
        );
        common::report("ablation.kernels", "row gather, scalar reference", &slow);
        println!(
            "ablation.kernels | gather {gbps:.2} GB/s on {} backend ({:.2}x vs scalar)",
            kernels::backend(),
            common::mean(&slow) / common::mean(&fast).max(1e-12)
        );
        common::metric("kernels.gather_gbps", gbps);

        // Filtered counts over adjacency-sized sorted runs (the
        // `neighbors_before` time cut): linear SIMD vs partition_point.
        let ts: Vec<i64> = (0..200i64).map(|i| i * 3).collect();
        let cuts: Vec<i64> = (0..10_000i64).map(|i| i % 650).collect();
        let cnt_fast = common::time_runs(3, 10, || {
            let mut acc = 0usize;
            for &c in &cuts {
                acc += kernels::count_lt(&ts, c);
            }
            acc
        });
        let cnt_slow = common::time_runs(3, 10, || {
            let mut acc = 0usize;
            for &c in &cuts {
                acc += kernels::count_lt_scalar(&ts, c);
            }
            acc
        });
        common::report(
            "ablation.kernels",
            &format!("count_lt 200-ts runs, {} backend", kernels::backend()),
            &cnt_fast,
        );
        common::report("ablation.kernels", "count_lt 200-ts runs, partition_point", &cnt_slow);
        println!(
            "ablation.kernels | count_lt {:.2}x vs partition_point on 200-ts runs",
            common::mean(&cnt_slow) / common::mean(&cnt_fast).max(1e-12)
        );
    }

    // 10. DTDG materialized views (`ablation.discretize`).
    if discretize_on {
        discretize_section(scale);
    }

    if sampler_on || ts_index_on {
        let data = gen::by_name("lastfm", 0.5 * scale, 42).unwrap();
        let storage = data.storage();
        let edges = storage.num_edges();
        println!("Ablations on lastfm surrogate ({edges} edges)");

        // 1. Sampler microbench: full pass over all batches, K=10. The
        //    recency sampler is stateful (Hook); uniform/naive are stateless
        //    worker-phase hooks (StatelessHook).
        if sampler_on {
            let batches = batches_of(storage, 200);
            let cfg = SamplerConfig {
                num_neighbors: 10,
                two_hop: None,
                include_features: true,
                seed_negatives: false,
            };
            let ctx = HookContext::new(storage, "bench");
            let run_stateless = |hook: &dyn StatelessHook| {
                for b in &batches {
                    let mut b = b.clone();
                    hook.apply(&mut b, &ctx).unwrap();
                }
            };
            let mut recency = RecencySampler::new(cfg.clone());
            let uniform = UniformSampler::new(cfg.clone(), 7);
            let naive = NaiveSampler::new(cfg.clone());
            let r = common::time_runs(1, 3, || {
                recency.reset();
                for b in &batches {
                    let mut b = b.clone();
                    Hook::apply(&mut recency, &mut b, &ctx).unwrap();
                }
            });
            let u = common::time_runs(1, 3, || run_stateless(&uniform));
            let nv = common::time_runs(1, 3, || run_stateless(&naive));
            common::report("ablation.sampler", "recency (circular buffer)", &r);
            common::report("ablation.sampler", "uniform (CSR)", &u);
            common::report("ablation.sampler", "naive (DyGLib history copies)", &nv);
            let samples_per_s = (2.0 * edges as f64) / common::mean(&r).max(1e-12);
            println!(
                "ablation.sampler | recency speedup vs naive: {:.2}x ({:.2}M samples/s)",
                common::mean(&nv) / common::mean(&r).max(1e-12),
                samples_per_s / 1e6
            );
            common::metric("sampler.samples_per_s", samples_per_s);
        }

        // 3. Cached timestamp index vs raw binary search.
        if ts_index_on {
            let ts = storage.edge_ts();
            let t_lo = storage.start_time();
            let t_hi = storage.end_time();
            let queries: Vec<(i64, i64)> = (0..10_000)
                .map(|i| {
                    let a = t_lo + (t_hi - t_lo) * (i % 100) / 100;
                    (a, a + (t_hi - t_lo) / 50)
                })
                .collect();
            let idx_secs = common::time_runs(1, 5, || {
                let mut acc = 0usize;
                for &(a, b) in &queries {
                    acc += storage.edge_range(a, b).len();
                }
                acc
            });
            let raw_secs = common::time_runs(1, 5, || {
                let mut acc = 0usize;
                for &(a, b) in &queries {
                    let lo = ts.partition_point(|&t| t < a);
                    let hi = ts.partition_point(|&t| t < b);
                    acc += hi - lo;
                }
                acc
            });
            common::report("ablation.ts_index", "cached unique-ts index", &idx_secs);
            common::report("ablation.ts_index", "raw event binary search", &raw_secs);
        }
    }

    // 2. Reduction operators.
    if reduce_on {
        for op in [ReduceOp::Count, ReduceOp::Sum, ReduceOp::Mean, ReduceOp::Last, ReduceOp::Max]
        {
            let wiki = gen::by_name("wiki", scale, 42).unwrap();
            let secs = common::time_runs(1, 3, || {
                discretize(wiki.storage(), TimeGranularity::Hour, op).unwrap()
            });
            common::report("ablation.reduce", &format!("{op:?}"), &secs);
        }
    }

    // 4. Device-boundary packing (§Perf): bulk byte view vs the
    //    per-element `to_le_bytes` collect the runtime originally used.
    if literal_on {
        let payload = vec![1.5f32; 2200 * 10 * 16]; // a cand_nbr_feats batch
        let t = tgm::util::Tensor::f32(payload.clone(), &[2200, 10, 16]).unwrap();
        let bulk = common::time_runs(2, 10, || {
            tgm::runtime::literal::tensor_to_literal(&t).unwrap()
        });
        let perelem = common::time_runs(2, 10, || {
            // The runtime's original path: per-element byte collect, then
            // the same literal constructor.
            let bytes: Vec<u8> = payload.iter().flat_map(|v| v.to_le_bytes()).collect();
            xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &[2200, 10, 16],
                &bytes,
            )
            .unwrap()
        });
        common::report("ablation.literal", "bulk byte view (current)", &bulk);
        common::report("ablation.literal", "per-element to_le_bytes (old)", &perelem);
        println!(
            "ablation.literal | speedup {:.2}x on a 1.4MB batch tensor",
            common::mean(&perelem) / common::mean(&bulk).max(1e-12)
        );
    }

    // 5. Serial vs prefetch batch materialization on the wiki surrogate
    //    (tgb_link "val" recipe: eval negatives -> dedup -> unique
    //    lookup, fully stateless, batch size 200). The consumer does no
    //    model work here, so this measures raw materialization
    //    throughput; the speedup target is >= 1.5x at 4 workers.
    if prefetch_on {
        let wiki = gen::by_name("wiki", scale, 42).unwrap();
        let view = wiki.full();
        let serial = common::time_runs(1, 3, || {
            let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
            m.activate("val").unwrap();
            let mut l = DGDataLoader::new(view.clone(), BatchBy::Events(200), &mut m).unwrap();
            l.collect_all().unwrap().len()
        });
        common::report("ablation.prefetch", "serial loader (baseline)", &serial);
        for workers in [1usize, 2, 4] {
            let secs = common::time_runs(1, 3, || {
                let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
                m.activate("val").unwrap();
                let mut l = PrefetchLoader::new(
                    view.clone(),
                    BatchBy::Events(200),
                    &mut m,
                    PrefetchConfig::default()
                        .with_workers(workers)
                        .with_queue_depth(2 * workers),
                )
                .unwrap();
                l.collect_all().unwrap().len()
            });
            common::report("ablation.prefetch", &format!("prefetch workers={workers}"), &secs);
            println!(
                "ablation.prefetch | speedup vs serial at {workers} workers: {:.2}x",
                common::mean(&serial) / common::mean(&secs).max(1e-12)
            );
        }
    }

    // Shared stream for sections 6 and 8: the wiki surrogate replayed
    // as an append stream.
    if streaming_on || persist_on {
        let wiki = gen::by_name("wiki", scale, 42).unwrap();
        let snap = wiki.storage();
        let events: Vec<tgm::graph::EdgeEvent> = (0..snap.num_edges())
            .map(|i| tgm::graph::EdgeEvent {
                t: snap.edge_ts_at(i),
                src: snap.edge_src_at(i),
                dst: snap.edge_dst_at(i),
                features: snap.edge_feat_row(i).to_vec(),
            })
            .collect();
        let n_events = events.len();
        let seal_every = (n_events / 4).max(1);

        // 6. Streaming ingestion. (a) ingestion throughput: append+seal+
        //    snapshot through the segmented store vs a one-shot from_events
        //    build of the same stream; (b) read overhead: materializing every
        //    planned batch from a 4-segment snapshot vs the compacted
        //    1-segment snapshot (acceptance target: segmented overhead < 15%).
        if streaming_on {
            let oneshot = common::time_runs(1, 3, || {
                GraphStorage::from_events(events.clone(), vec![], snap.num_nodes(), None, None)
                    .unwrap()
            });
            let streamed = common::time_runs(1, 3, || {
                let mut st = SegmentedStorage::new(
                    snap.num_nodes(),
                    SealPolicy::by_events(seal_every),
                );
                for e in &events {
                    st.append_edge(e.clone()).unwrap();
                }
                st.seal().unwrap();
                st.snapshot().unwrap().num_edges()
            });
            common::report("ablation.streaming", "one-shot from_events", &oneshot);
            common::report("ablation.streaming", "append+seal+snapshot (4 segments)", &streamed);
            let streamed_eps = n_events as f64 / common::mean(&streamed).max(1e-12);
            println!(
                "ablation.streaming | ingestion events/s streamed: {:.2}M (one-shot {:.2}M)",
                streamed_eps / 1e6,
                n_events as f64 / common::mean(&oneshot).max(1e-12) / 1e6
            );
            common::metric("streaming.ingest_events_per_s", streamed_eps);
            common::metric(
                "streaming.oneshot_events_per_s",
                n_events as f64 / common::mean(&oneshot).max(1e-12),
            );

            let mut segmented_store = SegmentedStorage::new(
                snap.num_nodes(),
                SealPolicy::by_events(seal_every),
            );
            for e in &events {
                segmented_store.append_edge(e.clone()).unwrap();
            }
            segmented_store.seal().unwrap();
            let segmented = segmented_store.snapshot().unwrap();
            segmented_store.compact().unwrap();
            let compacted = segmented_store.snapshot().unwrap();
            assert!(segmented.num_segments() >= 4 && compacted.num_segments() == 1);

            let materialize_all = |s: &std::sync::Arc<StorageSnapshot>| {
                let view = tgm::graph::DGraph::full(std::sync::Arc::clone(s));
                let plans = plan_batches(&view, BatchBy::Events(200), true, usize::MAX).unwrap();
                let mut edges = 0usize;
                for p in &plans {
                    edges += tgm::loader::materialize_window(s, p).unwrap().num_edges();
                }
                edges
            };
            let seg_secs = common::time_runs(1, 5, || materialize_all(&segmented));
            let comp_secs = common::time_runs(1, 5, || materialize_all(&compacted));
            common::report(
                "ablation.streaming",
                &format!("materialize over {} segments", segmented.num_segments()),
                &seg_secs,
            );
            common::report(
                "ablation.streaming",
                "materialize over compacted (1 segment)",
                &comp_secs,
            );
            let overhead_pct =
                (common::mean(&seg_secs) / common::mean(&comp_secs).max(1e-12) - 1.0) * 100.0;
            println!(
                "ablation.streaming | segmented-read overhead vs compacted: {overhead_pct:.1}% \
                 (target < 15%)"
            );
            common::metric("streaming.read_overhead_pct", overhead_pct);
        }

        // 8. Durable segment store (`ablation.persist`).
        if persist_on {
            persist_section(snap.num_nodes(), &events, seal_every);
        }
    }

    // 7. Sharded multi-tenant serving: aggregate throughput of T tenants
    //    each running a full "val" pass concurrently, (a) multiplexed
    //    over ONE shared ServingPool with a fixed total worker budget vs
    //    (b) per-tenant dedicated PrefetchLoaders splitting the same
    //    budget. Acceptance target: the shared pool stays within 20% of
    //    the dedicated loaders at 4 tenants.
    if sharded_on {
        let budget = 4usize;
        let (warmup, reps) = (1usize, 3usize);
        let tenant_data: Vec<tgm::graph::DGData> =
            (0..8u64).map(|i| gen::by_name("wiki", 0.25 * scale, 200 + i).unwrap()).collect();
        for t in [1usize, 2, 4, 8] {
            let data = &tenant_data[..t];
            let shared_batches = std::sync::atomic::AtomicUsize::new(0);
            let shared = common::time_runs(warmup, reps, || {
                let pool = tgm::loader::ServingPool::new(budget);
                std::thread::scope(|scope| {
                    for d in data {
                        let pool = &pool;
                        let shared_batches = &shared_batches;
                        scope.spawn(move || {
                            let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
                            m.activate("val").unwrap();
                            let mut s = pool
                                .stream(
                                    d.full(),
                                    BatchBy::Events(200),
                                    &mut m,
                                    tgm::loader::StreamConfig::default().with_queue_depth(4),
                                )
                                .unwrap();
                            let mut batches = 0usize;
                            while let Some(b) = s.next() {
                                b.unwrap();
                                batches += 1;
                            }
                            shared_batches
                                .fetch_add(batches, std::sync::atomic::Ordering::Relaxed);
                        });
                    }
                });
            });
            // A worker cannot be split below 1 per loader, so past
            // `budget` tenants the dedicated side necessarily runs MORE
            // total threads than the shared pool — labelled explicitly so
            // the over-budget rows aren't misread as shared-pool overhead.
            // The 4-tenant acceptance row is exactly budget-fair (4 = 4x1).
            let dedicated_workers = (budget / t).max(1);
            let dedicated_total = dedicated_workers * t;
            let dedicated_batches = std::sync::atomic::AtomicUsize::new(0);
            let dedicated = common::time_runs(warmup, reps, || {
                std::thread::scope(|scope| {
                    for d in data {
                        let dedicated_batches = &dedicated_batches;
                        scope.spawn(move || {
                            let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
                            m.activate("val").unwrap();
                            let mut l = PrefetchLoader::new(
                                d.full(),
                                BatchBy::Events(200),
                                &mut m,
                                PrefetchConfig::default()
                                    .with_workers(dedicated_workers)
                                    .with_queue_depth(4),
                            )
                            .unwrap();
                            let mut batches = 0usize;
                            while let Some(b) = l.next() {
                                b.unwrap();
                                batches += 1;
                            }
                            dedicated_batches
                                .fetch_add(batches, std::sync::atomic::Ordering::Relaxed);
                        });
                    }
                });
            });
            // Per timed run, both sides must have served the same batches.
            let runs = warmup + reps;
            let per_run = shared_batches.load(std::sync::atomic::Ordering::Relaxed) / runs;
            assert_eq!(
                per_run,
                dedicated_batches.load(std::sync::atomic::Ordering::Relaxed) / runs,
                "shared and dedicated passes must serve identical batch counts"
            );
            common::report(
                "ablation.sharded",
                &format!("{t} tenants, shared pool ({budget} workers)"),
                &shared,
            );
            common::report(
                "ablation.sharded",
                &format!(
                    "{t} tenants, dedicated loaders ({dedicated_workers}w x {t} = {dedicated_total}w total)"
                ),
                &dedicated,
            );
            let over_budget =
                if dedicated_total > budget { " [dedicated over-budget]" } else { "" };
            println!(
                "ablation.sharded | {t} tenants: shared {:.0} batches/s vs dedicated {:.0} \
                 batches/s (shared/dedicated = {:.2}x, target >= 0.8x at 4 tenants){over_budget}",
                per_run as f64 / common::mean(&shared).max(1e-12),
                per_run as f64 / common::mean(&dedicated).max(1e-12),
                common::mean(&dedicated) / common::mean(&shared).max(1e-12)
            );
            common::metric(
                &format!("sharded.shared_batches_per_s_{t}t"),
                per_run as f64 / common::mean(&shared).max(1e-12),
            );
        }
    }

    // 11. Point-query serving latency (`ablation.latency`).
    if latency_on {
        latency_section(scale);
    }

    // 12. Observability overhead (`ablation.obs`).
    if obs_on {
        obs_section(scale);
    }

    // 13. Replicated serving (`ablation.replica`).
    if replica_on {
        replica_section(scale);
    }
}

/// Section 12: observability overhead (`ablation.obs`).
///
/// The durable-ingest loop is the most metric-dense hot path in the
/// library: every `append_edge` increments the WAL append counter and
/// every seal records duration/byte metrics plus a trace span. Timing
/// the identical loop with the process-global registry recording vs
/// disabled (`MetricsRegistry::set_enabled(false)` — handles keep
/// working, they just skip the stores) bounds what instrumentation
/// costs on the paths users actually pay for. Target: <= 3% throughput
/// delta; the `obs.overhead_pct` row is tracked (null-gated) in
/// `bench-baseline.json` because its sign flips with runner jitter.
fn obs_section(scale: f64) {
    let wiki = gen::by_name("wiki", scale, 42).unwrap();
    let snap = wiki.storage();
    let events: Vec<tgm::graph::EdgeEvent> = (0..snap.num_edges())
        .map(|i| tgm::graph::EdgeEvent {
            t: snap.edge_ts_at(i),
            src: snap.edge_src_at(i),
            dst: snap.edge_dst_at(i),
            features: snap.edge_feat_row(i).to_vec(),
        })
        .collect();
    let n_events = events.len();
    let seal_every = (n_events / 4).max(1);
    let bench_dir =
        std::env::temp_dir().join(format!("tgm_ablation_obs_{}", std::process::id()));

    let run_seq = std::sync::atomic::AtomicUsize::new(0);
    let run_ingest = || {
        let run = run_seq.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut st = SegmentedStorage::new(snap.num_nodes(), SealPolicy::by_events(seal_every))
            .with_durability(DurabilityPolicy::new(bench_dir.join(format!("run-{run}"))))
            .unwrap();
        for e in &events {
            st.append_edge(e.clone()).unwrap();
        }
        st.seal().unwrap();
        st.total_edges()
    };

    let registry = tgm::obs::registry();
    assert!(registry.is_enabled(), "the global registry starts enabled");
    let instrumented = common::time_runs(1, 3, run_ingest);
    registry.set_enabled(false);
    let disabled = common::time_runs(1, 3, run_ingest);
    // This process is done measuring, but leave the global registry the
    // way every other section (and library user) expects it.
    registry.set_enabled(true);

    common::report("ablation.obs", "durable ingest, registry recording", &instrumented);
    common::report("ablation.obs", "durable ingest, registry disabled", &disabled);
    let overhead_pct =
        (common::mean(&instrumented) / common::mean(&disabled).max(1e-12) - 1.0) * 100.0;
    println!(
        "ablation.obs | metrics overhead on durable ingest: {overhead_pct:.2}% \
         ({:.2}M events/s instrumented, target <= 3%)",
        n_events as f64 / common::mean(&instrumented).max(1e-12) / 1e6
    );
    common::metric("obs.overhead_pct", overhead_pct);
    common::metric(
        "obs.instrumented_ingest_events_per_s",
        n_events as f64 / common::mean(&instrumented).max(1e-12),
    );

    let _ = std::fs::remove_dir_all(&bench_dir);
}

/// Section 11: point-query serving latency (`ablation.latency`).
///
/// p50/p99 latency and closed-loop throughput of the
/// zero-materialization point path (`ServingPool::point_query`) under
/// mixed load: while a hooked batch-scan stream and a streaming-ingest
/// thread run concurrently against the same machine and pool, the main
/// thread issues point queries one at a time and records exact
/// per-query wall latencies. The same questions answered through the
/// batch path — open a pooled stream, wait for its first materialized
/// and hooked batch, drop it — give the comparison row: the point path
/// skips batch planning, arena materialization, and hook execution
/// entirely, so its p99 should sit >= 10x below the one-batch-stream
/// equivalent.
fn latency_section(scale: f64) {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::Instant;
    use tgm::graph::{AdjacencyCache, PointQuery, PointReader};
    use tgm::loader::{QosTag, RequestClass, ServingPool, StreamConfig};

    /// Nearest-rank percentile over an ascending-sorted sample set.
    fn pctl(sorted: &[f64], p: f64) -> f64 {
        let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
        sorted[idx]
    }

    let wiki = gen::by_name("wiki", scale, 42).unwrap();
    let snap = wiki.storage();
    let reader = PointReader::with_cache(std::sync::Arc::clone(snap), &AdjacencyCache::new());
    let tag = QosTag::new("bench", RequestClass::PointQuery, 1);
    let num_nodes = snap.num_nodes() as u64;
    let end = snap.end_time() + 1;
    let events: Vec<tgm::graph::EdgeEvent> = (0..snap.num_edges())
        .map(|i| tgm::graph::EdgeEvent {
            t: snap.edge_ts_at(i),
            src: snap.edge_src_at(i),
            dst: snap.edge_dst_at(i),
            features: snap.edge_feat_row(i).to_vec(),
        })
        .collect();
    let seal_every = (events.len() / 4).max(1);

    let pool = ServingPool::new(4);
    let (warmup, queries) = (100u64, 1000u64);
    let stop = AtomicBool::new(false);
    let query_at = |i: u64| -> PointQuery {
        let node = ((i.wrapping_mul(0x9E37_79B9_7F4A_7C15)) % num_nodes) as u32;
        if i % 4 == 0 {
            let dst = ((i / 4 + 1) % num_nodes) as u32;
            PointQuery::EdgeLookup { src: node, dst, t: end }
        } else {
            PointQuery::NeighborsBefore { node, t: end, k: 10 }
        }
    };

    let (mut point_us, point_secs, mut batch_us) =
        std::thread::scope(|scope| -> (Vec<f64>, f64, Vec<f64>) {
            // Batch-scan load: hooked "val" passes over the full view,
            // restarted until the measurement finishes.
            let scan_pool = &pool;
            let scan_stop = &stop;
            let scan_data = &wiki;
            scope.spawn(move || {
                let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
                m.activate("val").unwrap();
                while !scan_stop.load(Ordering::SeqCst) {
                    let mut s = scan_pool
                        .stream(
                            scan_data.full(),
                            BatchBy::Events(200),
                            &mut m,
                            StreamConfig::default(),
                        )
                        .unwrap();
                    while let Some(b) = s.next() {
                        b.unwrap();
                        if scan_stop.load(Ordering::Relaxed) {
                            break;
                        }
                    }
                }
            });
            // Ingest load: streaming append+seal of the same event log,
            // repeated until the measurement finishes.
            let ingest_stop = &stop;
            let ingest_events = &events;
            scope.spawn(move || {
                while !ingest_stop.load(Ordering::SeqCst) {
                    let policy = SealPolicy::by_events(seal_every);
                    let mut st = SegmentedStorage::new(num_nodes as usize, policy);
                    for chunk in ingest_events.chunks(512) {
                        if ingest_stop.load(Ordering::Relaxed) {
                            return;
                        }
                        for e in chunk {
                            st.append_edge(e.clone()).unwrap();
                        }
                    }
                    st.seal().unwrap();
                }
            });

            // Closed-loop point queries on the caller, exact per-query
            // wall latencies (not the pool's log2 histogram buckets).
            let mut point_us = Vec::with_capacity(queries as usize);
            let mut measured = 0.0f64;
            for i in 0..(warmup + queries) {
                let t0 = Instant::now();
                pool.point_query(&reader, &tag, query_at(i)).unwrap();
                let secs = t0.elapsed().as_secs_f64();
                if i >= warmup {
                    point_us.push(secs * 1e6);
                    measured += secs;
                }
            }

            // One-batch-stream equivalent under the SAME mixed load:
            // per "query", open a pooled stream and wait for its first
            // materialized+hooked batch (the backlog of the dropped
            // stream's window drains in the pool, as a real abandoned
            // scan would).
            let mut m = RecipeRegistry::build(RECIPE_TGB_LINK).unwrap();
            m.activate("val").unwrap();
            let batch_reps = 40usize;
            let mut batch_us = Vec::with_capacity(batch_reps);
            for rep in 0..(1 + batch_reps) {
                let t0 = Instant::now();
                let mut s = pool
                    .stream(wiki.full(), BatchBy::Events(200), &mut m, StreamConfig::default())
                    .unwrap();
                s.next().expect("plan has at least one batch").unwrap();
                drop(s);
                if rep > 0 {
                    batch_us.push(t0.elapsed().as_secs_f64() * 1e6);
                }
            }
            stop.store(true, Ordering::SeqCst);
            (point_us, measured, batch_us)
        });

    point_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    batch_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (p50, p99) = (pctl(&point_us, 50.0), pctl(&point_us, 99.0));
    let batch_p99 = pctl(&batch_us, 99.0);
    let qps = queries as f64 / point_secs.max(1e-12);
    let speedup = batch_p99 / p99.max(1e-12);
    println!(
        "ablation.latency | point path under mixed load: p50 {p50:.0}us p99 {p99:.0}us \
         ({qps:.0} qps closed-loop)"
    );
    println!(
        "ablation.latency | one-batch-stream equivalent: p50 {:.0}us p99 {batch_p99:.0}us \
         (point p99 {speedup:.1}x lower, target >= 10x)",
        pctl(&batch_us, 50.0)
    );
    common::metric("latency.point_p50_us", p50);
    common::metric("latency.point_p99_us", p99);
    common::metric("latency.point_qps", qps);
    common::metric("latency.point_vs_batch_speedup", speedup);
}

/// Section 10: DTDG materialized views (`ablation.discretize`).
///
/// (a) Maintaining an hourly view over a live ingest stream: one
///     registered `DtdgView` refreshed incrementally on every seal vs
///     rescanning (`discretize()`) the full snapshot after every seal,
///     at 4/16/64 seals. The rescan redoes O(total) work per seal, the
///     view only touches the new segment plus the trailing partial
///     bucket — the gap widens with seal count (target: >= 5x at 64).
/// (b) The vectorized one-shot discretization pass vs the UTG
///     (unified-temporal-graph, scalar hash-map) baseline it replaced.
fn discretize_section(scale: f64) {
    let wiki = gen::by_name("wiki", scale, 42).unwrap();
    let snap = wiki.storage();
    let events: Vec<tgm::graph::EdgeEvent> = (0..snap.num_edges())
        .map(|i| tgm::graph::EdgeEvent {
            t: snap.edge_ts_at(i),
            src: snap.edge_src_at(i),
            dst: snap.edge_dst_at(i),
            features: snap.edge_feat_row(i).to_vec(),
        })
        .collect();
    let n_events = events.len();
    let (target, reduce) = (TimeGranularity::Hour, ReduceOp::Mean);

    // Sanity outside the timed region: the incremental view ends up with
    // exactly the coarse graph a full rescan produces.
    {
        let mut st = SegmentedStorage::new(
            snap.num_nodes(),
            SealPolicy::by_events((n_events / 16).max(1)),
        );
        let view = st.register_dtdg_view(target, reduce).unwrap();
        for e in &events {
            st.append_edge(e.clone()).unwrap();
        }
        st.seal().unwrap();
        let want = discretize(&st.snapshot().unwrap(), target, reduce).unwrap();
        assert_eq!(view.pin().unwrap().num_edges(), want.num_edges());
    }

    for n_seals in [4usize, 16, 64] {
        let per_seal = n_events.div_ceil(n_seals).max(1);
        let incremental = common::time_runs(1, 3, || {
            let mut st = SegmentedStorage::new(snap.num_nodes(), SealPolicy::by_events(per_seal));
            let view = st.register_dtdg_view(target, reduce).unwrap();
            for e in &events {
                st.append_edge(e.clone()).unwrap();
            }
            st.seal().unwrap();
            view.pin().unwrap().num_edges()
        });
        let rescan = common::time_runs(1, 3, || {
            let mut st = SegmentedStorage::new(snap.num_nodes(), SealPolicy::by_events(per_seal));
            let mut coarse = 0usize;
            for e in &events {
                if st.append_edge(e.clone()).unwrap() {
                    coarse = discretize(&st.snapshot().unwrap(), target, reduce)
                        .unwrap()
                        .num_edges();
                }
            }
            if st.seal().unwrap() {
                coarse = discretize(&st.snapshot().unwrap(), target, reduce)
                    .unwrap()
                    .num_edges();
            }
            coarse
        });
        common::report(
            "ablation.discretize",
            &format!("incremental view refresh ({n_seals} seals)"),
            &incremental,
        );
        common::report(
            "ablation.discretize",
            &format!("full rescan per seal ({n_seals} seals)"),
            &rescan,
        );
        println!(
            "ablation.discretize | {n_seals} seals: incremental {:.2}M events/s vs rescan \
             {:.2}M events/s ({:.1}x, target >= 5x at 64 seals)",
            n_events as f64 / common::mean(&incremental).max(1e-12) / 1e6,
            n_events as f64 / common::mean(&rescan).max(1e-12) / 1e6,
            common::mean(&rescan) / common::mean(&incremental).max(1e-12)
        );
        if n_seals == 64 {
            common::metric(
                "discretize.refresh_events_per_s",
                n_events as f64 / common::mean(&incremental).max(1e-12),
            );
            common::metric(
                "discretize.full_rescan_events_per_s",
                n_events as f64 / common::mean(&rescan).max(1e-12),
            );
        }
    }

    // (b) One-shot pass: vectorized kernels vs the UTG scalar baseline.
    let vectorized =
        common::time_runs(1, 3, || discretize(snap, target, reduce).unwrap().num_edges());
    let utg =
        common::time_runs(1, 3, || discretize_utg(snap, target, reduce).unwrap().num_edges());
    common::report("ablation.discretize", "one-shot vectorized pass", &vectorized);
    common::report("ablation.discretize", "one-shot UTG baseline", &utg);
    println!(
        "ablation.discretize | one-shot vectorized vs UTG: {:.2}x ({:.2}M events/s)",
        common::mean(&utg) / common::mean(&vectorized).max(1e-12),
        n_events as f64 / common::mean(&vectorized).max(1e-12) / 1e6
    );
}

/// Section 8: the durable segment store. (a) WAL-on vs in-memory ingest;
/// (b) recovery time vs sealed-segment count; (c) tiered vs full
/// compaction write amplification under sustained ingest at 16/64
/// sealed segments; (d) per-append fsync vs group-commit throughput.
fn persist_section(num_nodes: usize, events: &[tgm::graph::EdgeEvent], seal_every: usize) {
    let n_events = events.len();
    let bench_dir =
        std::env::temp_dir().join(format!("tgm_ablation_persist_{}", std::process::id()));

    // (a) WAL overhead on the ingest path.
    let mem_ingest = common::time_runs(1, 3, || {
        let mut st = SegmentedStorage::new(num_nodes, SealPolicy::by_events(seal_every));
        for e in events {
            st.append_edge(e.clone()).unwrap();
        }
        st.seal().unwrap();
        st.total_edges()
    });
    // Each run gets its own fresh subdirectory so the timed region
    // holds only the durable-ingest work, not remove_dir_all of the
    // previous run's segment files.
    let wal_run = std::sync::atomic::AtomicUsize::new(0);
    let wal_ingest = common::time_runs(1, 3, || {
        let run = wal_run.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut st = SegmentedStorage::new(num_nodes, SealPolicy::by_events(seal_every))
            .with_durability(DurabilityPolicy::new(bench_dir.join(format!("ingest-{run}"))))
            .unwrap();
        for e in events {
            st.append_edge(e.clone()).unwrap();
        }
        st.seal().unwrap();
        st.total_edges()
    });
    common::report("ablation.persist", "in-memory ingest (baseline)", &mem_ingest);
    common::report("ablation.persist", "durable ingest (WAL on)", &wal_ingest);
    let durable_eps = n_events as f64 / common::mean(&wal_ingest).max(1e-12);
    println!(
        "ablation.persist | ingest events/s: durable {:.2}M vs in-memory {:.2}M \
         ({:.1}% WAL overhead)",
        durable_eps / 1e6,
        n_events as f64 / common::mean(&mem_ingest).max(1e-12) / 1e6,
        (common::mean(&wal_ingest) / common::mean(&mem_ingest).max(1e-12) - 1.0) * 100.0
    );
    common::metric("persist.durable_ingest_events_per_s", durable_eps);
    common::metric(
        "persist.mem_ingest_events_per_s",
        n_events as f64 / common::mean(&mem_ingest).max(1e-12),
    );

    // (b) Recovery time vs sealed-segment count (heap and mmap backing).
    for target_segs in [1usize, 4, 16] {
        let _ = std::fs::remove_dir_all(&bench_dir);
        let per_seg = n_events.div_ceil(target_segs).max(1);
        let mut st = SegmentedStorage::new(num_nodes, SealPolicy::by_events(per_seg))
            .with_durability(DurabilityPolicy::new(&bench_dir))
            .unwrap();
        for e in events {
            st.append_edge(e.clone()).unwrap();
        }
        st.seal().unwrap();
        let actual = st.num_sealed_segments();
        drop(st);
        // `recover` is idempotent over an unchanged directory (it only
        // resets the — here empty — WAL), so repeated timing is sound.
        let rec = common::time_runs(1, 3, || {
            tgm::persist::recover(
                SealPolicy::by_events(per_seg),
                DurabilityPolicy::new(&bench_dir),
            )
            .unwrap()
            .total_edges()
        });
        common::report(
            "ablation.persist",
            &format!("recover ({actual} sealed segments, {n_events} events)"),
            &rec,
        );
        let rec_mmap = common::time_runs(1, 3, || {
            tgm::persist::recover(
                SealPolicy::by_events(per_seg),
                DurabilityPolicy::new(&bench_dir).with_backing(SegmentBacking::Mmap),
            )
            .unwrap()
            .total_edges()
        });
        common::report(
            "ablation.persist",
            &format!("recover mmap-backed ({actual} sealed segments)"),
            &rec_mmap,
        );
        println!(
            "ablation.persist | recovery at {actual} segments: heap {:.1}ms, mmap {:.1}ms \
             ({:.2}M events/s heap)",
            common::mean(&rec) * 1e3,
            common::mean(&rec_mmap) * 1e3,
            n_events as f64 / common::mean(&rec).max(1e-12) / 1e6
        );
        common::metric(
            &format!("persist.recover_events_per_s_{target_segs}segs"),
            n_events as f64 / common::mean(&rec).max(1e-12),
        );
        common::metric(
            &format!("persist.recover_mmap_events_per_s_{target_segs}segs"),
            n_events as f64 / common::mean(&rec_mmap).max(1e-12),
        );
    }

    // (c) Write amplification under sustained ingest: full compaction
    //     (merge the whole stack whenever > 4 segments pile up) vs
    //     tiered (fanout 4, driven to its fixpoint after every seal).
    //     amp = compaction bytes written / logical data bytes; the
    //     in-memory byte accounting equals what a durable store would
    //     write to disk for the same rounds.
    for target_segs in [16usize, 64] {
        let per_seg = n_events.div_ceil(target_segs).max(1);
        let data_bytes = {
            let mut st = SegmentedStorage::new(num_nodes, SealPolicy::by_events(per_seg));
            for e in events {
                st.append_edge(e.clone()).unwrap();
            }
            st.seal().unwrap();
            st.snapshot().unwrap().byte_size().max(1)
        };
        let mut full = SegmentedStorage::new(num_nodes, SealPolicy::by_events(per_seg));
        let full_secs = common::time_runs(0, 1, || {
            for e in events {
                if full.append_edge(e.clone()).unwrap() {
                    full.maybe_compact(4).unwrap();
                }
            }
            full.seal().unwrap();
            full.compact().unwrap();
        });
        let mut tiered = SegmentedStorage::new(num_nodes, SealPolicy::by_events(per_seg));
        let tiered_secs = common::time_runs(0, 1, || {
            for e in events {
                if tiered.append_edge(e.clone()).unwrap() {
                    while tiered.compact_tiered(4).unwrap().is_some() {}
                }
            }
            tiered.seal().unwrap();
            while tiered.compact_tiered(4).unwrap().is_some() {}
        });
        let full_amp = full.compaction_bytes() as f64 / data_bytes as f64;
        let tiered_amp = tiered.compaction_bytes() as f64 / data_bytes as f64;
        common::report(
            "ablation.persist",
            &format!("full compaction under ingest ({target_segs} seals)"),
            &full_secs,
        );
        common::report(
            "ablation.persist",
            &format!("tiered compaction under ingest ({target_segs} seals)"),
            &tiered_secs,
        );
        println!(
            "ablation.persist | write amplification at {target_segs} seals: \
             full {full_amp:.2}x vs tiered {tiered_amp:.2}x \
             ({} vs {} sealed segments at the end)",
            full.num_sealed_segments(),
            tiered.num_sealed_segments()
        );
        common::metric(&format!("persist.write_amp_full_{target_segs}"), full_amp);
        common::metric(&format!("persist.write_amp_tiered_{target_segs}"), tiered_amp);
    }

    // (d) fsync-per-append vs group commit (one barrier per 64-event
    //     chunk). Small event count: every append costs a disk sync on
    //     the left side.
    let n_sync = n_events.min(512);
    let _ = std::fs::remove_dir_all(&bench_dir);
    let mut st = SegmentedStorage::new(num_nodes, SealPolicy::by_events(usize::MAX))
        .with_durability(DurabilityPolicy::new(bench_dir.join("fsync-each")).with_fsync())
        .unwrap();
    let each_secs = common::time_runs(0, 1, || {
        for e in &events[..n_sync] {
            st.append_edge(e.clone()).unwrap();
        }
    });
    drop(st);
    let mut st = SegmentedStorage::new(num_nodes, SealPolicy::by_events(usize::MAX))
        .with_durability(DurabilityPolicy {
            fsync_appends: true,
            group_commit: true,
            ..DurabilityPolicy::new(bench_dir.join("group-commit"))
        })
        .unwrap();
    let group_secs = common::time_runs(0, 1, || {
        for (i, e) in events[..n_sync].iter().enumerate() {
            st.append_edge(e.clone()).unwrap();
            if i % 64 == 63 {
                st.sync_wal().unwrap();
            }
        }
        st.sync_wal().unwrap();
    });
    drop(st);
    common::report(
        "ablation.persist",
        &format!("fsync per append ({n_sync} events)"),
        &each_secs,
    );
    common::report(
        "ablation.persist",
        &format!("group commit, barrier per 64 ({n_sync} events)"),
        &group_secs,
    );
    let each_eps = n_sync as f64 / common::mean(&each_secs).max(1e-12);
    let group_eps = n_sync as f64 / common::mean(&group_secs).max(1e-12);
    println!(
        "ablation.persist | fsync throughput: per-append {:.1}k events/s vs group commit \
         {:.1}k events/s ({:.1}x)",
        each_eps / 1e3,
        group_eps / 1e3,
        group_eps / each_eps.max(1e-12)
    );
    common::metric("persist.fsync_each_events_per_s", each_eps);
    common::metric("persist.group_commit_events_per_s", group_eps);

    let _ = std::fs::remove_dir_all(&bench_dir);
}

/// Section 13: replicated serving (`ablation.replica`).
///
/// Two costs define the replica tier. (a) Bootstrap: copying the
/// primary's sealed segment files plus static table (no primary lock
/// taken), opening them mmap-backed, and replaying the WAL tail —
/// measured as end-to-end events/s into a fresh replica directory at
/// 1/4/16 sealed segments. (b) Read scaling: aggregate closed-loop
/// point-query QPS when every read is answered by a replica (the
/// primary serves none), at 1/2/4 tailing replicas over one shared
/// pool. The `1r` floor is gated conservatively like
/// `latency.point_qps`; the scaling rows are tracked un-gated because
/// 2-core CI runners flatten them.
fn replica_section(scale: f64) {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use tgm::graph::PointQuery;
    use tgm::loader::ServingPool;
    use tgm::replica::{DirTransport, Replica, ReplicaConfig};
    use tgm::serving::{ReadHandle, ServingConfig, TenantId, TenantRouter};

    let wiki = gen::by_name("wiki", scale, 77).unwrap();
    let snap = wiki.storage();
    let n_events = snap.num_edges();
    let events: Vec<tgm::graph::EdgeEvent> = (0..n_events)
        .map(|i| tgm::graph::EdgeEvent {
            t: snap.edge_ts_at(i),
            src: snap.edge_src_at(i),
            dst: snap.edge_dst_at(i),
            features: snap.edge_feat_row(i).to_vec(),
        })
        .collect();
    let base =
        std::env::temp_dir().join(format!("tgm_ablation_replica_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);

    // (a) Bootstrap throughput vs sealed-segment count. The primary
    // stays alive (directory locked) — bootstrap reads around the lock.
    let run_seq = AtomicUsize::new(0);
    for segs in [1usize, 4, 16] {
        let pdir = base.join(format!("primary-{segs}"));
        let mut st = SegmentedStorage::new(
            snap.num_nodes(),
            SealPolicy::by_events(n_events.div_ceil(segs).max(1)),
        )
        .with_granularity(snap.granularity())
        .with_durability(DurabilityPolicy::new(&pdir))
        .unwrap();
        for e in &events {
            st.append_edge(e.clone()).unwrap();
        }
        st.seal().unwrap();
        let log = Arc::new(DirTransport::new(&pdir));
        let secs = common::time_runs(1, 3, || {
            let rdir =
                base.join(format!("boot-{segs}-{}", run_seq.fetch_add(1, Ordering::Relaxed)));
            let (replica, report) = Replica::bootstrap(
                format!("boot-{segs}"),
                Arc::clone(&log),
                ReplicaConfig::new(rdir),
            )
            .unwrap();
            assert!(report.shipped_bytes > 0, "a fresh dir must fetch segments");
            replica.total_edges()
        });
        common::report(
            "ablation.replica",
            &format!("bootstrap, {segs} sealed segments"),
            &secs,
        );
        common::metric(
            &format!("replica.bootstrap_events_per_s_{segs}segs"),
            n_events as f64 / common::mean(&secs).max(1e-12),
        );
        drop(st);
    }

    // (b) Aggregate point QPS with every read served by a replica.
    let pdir = base.join("primary-serve");
    {
        let mut st = SegmentedStorage::new(
            snap.num_nodes(),
            SealPolicy::by_events((n_events / 8).max(1)),
        )
        .with_granularity(snap.granularity())
        .with_durability(DurabilityPolicy::new(&pdir))
        .unwrap();
        for e in &events {
            st.append_edge(e.clone()).unwrap();
        }
    } // drop: releases the primary directory lock for the router
    let n_nodes = snap.num_nodes() as u64;
    let queries_total = ((2000.0 * scale.max(0.05)) as usize).max(400);
    let mut qps_1r = 0.0f64;
    for n_replicas in [1usize, 2, 4] {
        let mut router = TenantRouter::new();
        let id = TenantId::from("serve");
        router
            .add_primary(
                id.clone(),
                ServingConfig::primary(snap.num_nodes(), &pdir)
                    .seal(SealPolicy::by_events((n_events / 8).max(1))),
            )
            .unwrap();
        let mut handles: Vec<Arc<dyn ReadHandle>> = Vec::new();
        for r in 0..n_replicas {
            handles.push(router.add_replica(
                id.clone(),
                ServingConfig::replica(&pdir, base.join(format!("serve-{n_replicas}-{r}"))),
            )
            .unwrap());
        }
        let pool = ServingPool::new(4);
        let per = queries_total / n_replicas;
        let t0 = std::time::Instant::now();
        std::thread::scope(|scope| {
            for h in &handles {
                let pool = &pool;
                scope.spawn(move || {
                    let snap = h.pin().unwrap();
                    let end = snap.end_time() + 1;
                    for i in 0..per {
                        let node =
                            ((i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) % n_nodes) as u32;
                        h.query(pool, PointQuery::NeighborsBefore { node, t: end, k: 10 })
                            .unwrap();
                    }
                });
            }
        });
        let qps = (per * n_replicas) as f64 / t0.elapsed().as_secs_f64().max(1e-12);
        if n_replicas == 1 {
            qps_1r = qps;
        }
        println!(
            "ablation.replica | {n_replicas} replicas, {} queries each: {qps:.0} aggregate \
             point QPS ({:.2}x vs 1 replica)",
            per,
            qps / qps_1r.max(1e-12)
        );
        common::metric(&format!("replica.point_qps_{n_replicas}r"), qps);
        drop(router); // release the primary dir lock for the next config
    }

    let _ = std::fs::remove_dir_all(&base);
}
