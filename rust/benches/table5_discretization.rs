//! Table 5: discretization latency to hourly snapshots — TGM's vectorized
//! path vs the UTG-style per-event hash-map baseline.
//!
//! The paper reports 49–433x against UTG's *Python* implementation; both
//! sides here are Rust, so the ratio compresses to the pure algorithmic
//! gap (no per-event boxed allocation / pointer chasing), but the shape —
//! TGM wins on every dataset, most on the largest — must hold.

#[path = "common.rs"]
mod common;

use tgm::graph::{discretize, discretize_utg, ReduceOp};
use tgm::io::gen;
use tgm::util::TimeGranularity;

fn main() {
    let scale = common::bench_scale();
    println!("Table 5: discretization latency to hourly snapshots (TGM vs UTG baseline)");
    for ds in ["wiki", "reddit", "lastfm"] {
        let data = gen::by_name(ds, scale, 42).unwrap();
        let storage = data.storage();
        let edges = storage.num_edges();

        let tgm_secs = common::time_runs(1, 5, || {
            discretize(storage, TimeGranularity::Hour, ReduceOp::Count).unwrap()
        });
        let utg_secs = common::time_runs(1, 5, || {
            discretize_utg(storage, TimeGranularity::Hour, ReduceOp::Count).unwrap()
        });
        common::report("table5", &format!("{ds} ({edges} edges) TGM vectorized"), &tgm_secs);
        common::report("table5", &format!("{ds} ({edges} edges) UTG baseline"), &utg_secs);
        println!(
            "table5 | {ds} speedup: {:.2}x ({:.1}M edges/s vectorized)",
            common::mean(&utg_secs) / common::mean(&tgm_secs).max(1e-12),
            edges as f64 / common::mean(&tgm_secs).max(1e-12) / 1e6
        );
    }
}
