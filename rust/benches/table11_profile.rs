//! Table 11: runtime breakdown of TGAT training on the LastFM surrogate
//! (the paper's cProfile decomposition: data loading / hooks / sampler /
//! model execute / packing). Uses TGM's built-in profiler.

#[path = "common.rs"]
mod common;

use tgm::coordinator::{Pipeline, PipelineConfig};
use tgm::io::gen;

fn main() {
    let Some(engine) = common::engine_or_skip("table11") else { return };
    let scale = 0.05 * common::bench_scale();
    println!("Table 11: TGAT runtime breakdown (lastfm surrogate)");
    let data = gen::by_name("lastfm", scale, 42).unwrap();
    let mut pipe = Pipeline::new(&engine, data, PipelineConfig::new("tgat_link")).unwrap();
    pipe.profiler.start_wall();
    let r = pipe.train_epoch().unwrap();
    println!("table11 | loss={:.4} batches={}", r.mean_loss, r.batches);
    for (cat, secs, pct) in pipe.profiler.report() {
        println!("table11 | {cat:<24} {secs:>9.4}s {pct:>6.2}%");
    }
}
