//! Shared bench harness (criterion is unavailable offline; this provides
//! warmup + repeated timing with mean/std reporting in a stable format).

// Included via `#[path]` by every bench; not all benches use every item.
#![allow(dead_code)]

use std::time::Instant;

/// Time `f` over `reps` runs after `warmup` runs; returns per-run secs.
pub fn time_runs<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

/// Mean of samples.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Print one result row: `<table> | <label> | mean ± std over n`.
pub fn report(table: &str, label: &str, secs: &[f64]) {
    let m = mean(secs);
    let var = if secs.len() > 1 {
        secs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (secs.len() - 1) as f64
    } else {
        0.0
    };
    println!("{table} | {label:<40} | {m:>10.4}s ± {:>7.4}s (n={})", var.sqrt(), secs.len());
}

/// Scale factor override for bench sizing: `TGM_BENCH_SCALE` (default 1).
pub fn bench_scale() -> f64 {
    std::env::var("TGM_BENCH_SCALE").ok().and_then(|v| v.parse().ok()).unwrap_or(1.0)
}

/// Section filter: `TGM_ABLATION=streaming,persist` runs only those
/// sections of `benches/ablations.rs` (unset = all). CI's
/// bench-regression job uses it to run just the gated sections.
pub fn section_enabled(name: &str) -> bool {
    match std::env::var("TGM_ABLATION") {
        Err(_) => true,
        Ok(list) => list.split(',').any(|s| s.trim().eq_ignore_ascii_case(name)),
    }
}

/// Machine-readable metric row for the CI bench-regression gate:
/// `scripts/bench_gate.py` collects every `BENCH_METRIC <name> <value>`
/// line into `BENCH_PR5.json` and compares gated names against the
/// committed `bench-baseline.json`.
pub fn metric(name: &str, value: f64) {
    println!("BENCH_METRIC {name} {value:.4}");
}

/// Skip helper when artifacts are missing (benches needing PJRT).
pub fn engine_or_skip(table: &str) -> Option<tgm::runtime::XlaEngine> {
    let dir = std::env::var("TGM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    match tgm::runtime::XlaEngine::cpu(&dir) {
        Ok(e) => Some(e),
        Err(err) => {
            println!("{table} | SKIPPED: artifacts unavailable ({err})");
            None
        }
    }
}
