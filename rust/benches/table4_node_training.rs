//! Table 4: training time per epoch for dynamic node property prediction
//! on the Trade (yearly) and Genre (weekly) surrogates. TGM uniquely
//! supports message-passing (TGN), transformer (DyGFormer) and snapshot
//! (GCN/GCLSTM/T-GCN) models on this task.

#[path = "common.rs"]
mod common;

use tgm::coordinator::{Pipeline, PipelineConfig};
use tgm::io::gen;
use tgm::util::TimeGranularity;

fn main() {
    let Some(engine) = common::engine_or_skip("table4") else { return };
    let scale = common::bench_scale();
    println!("Table 4: node-property training time per epoch (s)");
    let cases = [
        ("trade", 0.5 * scale, TimeGranularity::Year),
        ("genre", 0.15 * scale, TimeGranularity::Week),
    ];
    let models = ["tgn_node", "dygformer_node", "gcn_node", "gclstm_node", "tgcn_node"];
    for (ds, s, gran) in cases {
        for model in models {
            let data = gen::by_name(ds, s, 42).unwrap();
            let mut cfg = PipelineConfig::new(model);
            cfg.granularity = gran;
            let mut pipe = Pipeline::new(&engine, data, cfg).unwrap();
            let secs = common::time_runs(1, 2, || pipe.train_epoch().unwrap());
            common::report("table4", &format!("{ds:<8} {model}"), &secs);
        }
    }
}
