//! Table 9: validation time per epoch under the TGB one-vs-many
//! protocol. TGM's batch-level dedup (sample once per unique node) vs
//! the DyGLib-style naive mode (re-sample per (seed, candidate) slot),
//! plus the EdgeBank baseline. MRRs must agree between the two modes —
//! only the data-path cost differs (paper: up to 246x on TGN/Wikipedia).

#[path = "common.rs"]
mod common;

use tgm::coordinator::{evaluate_edgebank, Pipeline, PipelineConfig, Split};
use tgm::io::gen;
use tgm::models::EdgeBankMode;

fn main() {
    let Some(engine) = common::engine_or_skip("table9") else { return };
    let scale = 0.15 * common::bench_scale();
    println!("Table 9: one-vs-many validation time (s), dedup vs naive");
    for ds in ["wiki", "reddit"] {
        // EdgeBank row (pure Rust).
        let data = gen::by_name(ds, scale, 42).unwrap();
        let splits = data.split().unwrap();
        let eb = evaluate_edgebank(&data, &splits.val, EdgeBankMode::Unlimited, 10, 0).unwrap();
        common::report("table9", &format!("{ds:<8} edgebank"), &[eb.seconds]);

        for model in ["tgn_link", "graphmixer_link"] {
            // Two identically trained pipelines (deterministic seeds), so
            // stateful models (TGN memory advances during eval) see the
            // same state in both eval modes.
            let mk = || {
                let data = gen::by_name(ds, scale, 42).unwrap();
                let mut p = Pipeline::new(&engine, data, PipelineConfig::new(model)).unwrap();
                p.train_epoch().unwrap();
                p
            };
            let mut pipe = mk();
            let fast = pipe.evaluate(Split::Val).unwrap();
            let mut pipe_naive = mk();
            let naive = pipe_naive.evaluate_link_naive(Split::Val).unwrap();
            common::report("table9", &format!("{ds:<8} {model:<17} TGM dedup"), &[fast.seconds]);
            common::report("table9", &format!("{ds:<8} {model:<17} naive"), &[naive.seconds]);
            let agree = (fast.mrr.unwrap() - naive.mrr.unwrap()).abs() < 1e-6;
            println!(
                "table9 | {ds} {model}: data-path speedup {:.2}x, MRR {:.4} vs {:.4} ({})",
                naive.seconds / fast.seconds.max(1e-12),
                fast.mrr.unwrap(),
                naive.mrr.unwrap(),
                if agree { "identical" } else { "DIFFER" }
            );
        }
    }
}
