//! Table 3: training time per epoch for link property prediction.
//!
//! TGM's pipeline (circular-buffer recency sampler) vs the DyGLib-style
//! baseline pipeline (per-seed history-copy sampler) for each model and
//! dataset surrogate. The paper's absolute numbers come from an A100;
//! here the *shape* — TGM's data path never slower, biggest gaps on
//! sampler-bound models and high-degree graphs — is what's reproduced.
//! Surrogates run at a reduced scale (override: TGM_BENCH_SCALE).

#[path = "common.rs"]
mod common;

use tgm::coordinator::{Pipeline, PipelineConfig};
use tgm::hooks::SamplerKind;
use tgm::io::gen;
use tgm::util::TimeGranularity;

fn main() {
    let Some(engine) = common::engine_or_skip("table3") else { return };
    let scale = 0.1 * common::bench_scale();
    println!("Table 3: link-prediction training time per epoch (s)");
    let models =
        ["tpnet_link", "tgn_link", "graphmixer_link", "dygformer_link", "tgat_link", "gcn_link", "gclstm_link"];
    for ds in ["wiki", "reddit", "lastfm"] {
        for model in models {
            for (label, sampler) in
                [("TGM/recency", SamplerKind::Recency), ("DyGLib-style/naive", SamplerKind::Naive)]
            {
                // Samplers only matter for neighbor-based CTDG models.
                let neighbor_based = !model.starts_with("gc") && model != "tpnet_link";
                if !neighbor_based && sampler == SamplerKind::Naive {
                    continue;
                }
                let data = gen::by_name(ds, scale, 42).unwrap();
                let mut cfg = PipelineConfig::new(model);
                cfg.sampler = sampler;
                cfg.granularity = TimeGranularity::Day;
                let mut pipe = Pipeline::new(&engine, data, cfg).unwrap();
                let secs = common::time_runs(1, 2, || pipe.train_epoch().unwrap());
                common::report("table3", &format!("{ds:<8} {model:<17} {label}"), &secs);
            }
        }
    }
}
