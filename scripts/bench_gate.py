#!/usr/bin/env python3
"""CI bench-regression gate.

Collects ``BENCH_METRIC <name> <value>`` rows printed by
``cargo bench --bench ablations`` (see ``benches/common.rs::metric``),
writes them to a JSON summary artifact (``BENCH_PR5.json``), and fails
when any metric named in the committed baseline's ``gates`` map regressed
by more than ``tolerance`` (throughput metrics: measured must be at least
``baseline * (1 - tolerance)``). The baseline's ``ceilings`` map gates
lower-is-better metrics (e.g. ``latency.point_p99_us``) the other way:
measured must be at most ``baseline * (1 + tolerance)``.

Usage:
    bench_gate.py --baseline bench-baseline.json --output BENCH_PR5.json LOG...
    bench_gate.py --write-baseline --baseline bench-baseline.json LOG...
    bench_gate.py --self-test LOG...

``--write-baseline`` refreshes the baseline's gate values from the
measured log (run it on a quiet machine, commit the result).

``--self-test`` proves the gate can fail: it fabricates a sandbagged
baseline (every gated metric 10x the measured value) and exits 0 only if
the comparison correctly reports regressions — guarding against the gate
rotting into a rubber stamp.

Opt-out: the workflow skips the job when the PR carries the
``skip-bench-gate`` label (documented in ``.github/workflows/ci.yml``).
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

METRIC_RE = re.compile(r"^BENCH_METRIC\s+(\S+)\s+([-+0-9.eE]+)\s*$")


def collect_metrics(paths: list[str]) -> dict[str, float]:
    """Last value wins when a metric is printed twice."""
    metrics: dict[str, float] = {}
    for path in paths:
        for line in Path(path).read_text().splitlines():
            m = METRIC_RE.match(line.strip())
            if m:
                metrics[m.group(1)] = float(m.group(2))
    return metrics


def compare(metrics: dict[str, float], baseline: dict) -> list[str]:
    """Return human-readable failure rows (empty == gate passes)."""
    tolerance = float(baseline.get("tolerance", 0.20))
    failures = []
    for name, base in sorted(baseline.get("gates", {}).items()):
        if base is None:
            continue  # recorded but not gated
        measured = metrics.get(name)
        if measured is None:
            failures.append(
                f"{name}: gated metric missing from the bench log "
                "(did the bench section fail to run?)"
            )
            continue
        floor = float(base) * (1.0 - tolerance)
        if measured < floor:
            drop = 100.0 * (1.0 - measured / float(base))
            failures.append(
                f"{name}: {measured:.1f} is {drop:.1f}% below baseline "
                f"{float(base):.1f} (tolerance {tolerance:.0%})"
            )
    for name, base in sorted(baseline.get("ceilings", {}).items()):
        if base is None:
            continue  # recorded but not gated
        measured = metrics.get(name)
        if measured is None:
            failures.append(
                f"{name}: gated metric missing from the bench log "
                "(did the bench section fail to run?)"
            )
            continue
        ceiling = float(base) * (1.0 + tolerance)
        if measured > ceiling:
            rise = 100.0 * (measured / float(base) - 1.0)
            failures.append(
                f"{name}: {measured:.1f} is {rise:.1f}% above ceiling "
                f"{float(base):.1f} (tolerance {tolerance:.0%})"
            )
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("logs", nargs="+", help="bench output file(s) to scan")
    ap.add_argument("--baseline", default="bench-baseline.json")
    ap.add_argument("--output", default=None, help="write the metric summary JSON here")
    ap.add_argument("--write-baseline", action="store_true")
    ap.add_argument("--self-test", action="store_true")
    args = ap.parse_args()

    metrics = collect_metrics(args.logs)
    if not metrics:
        print("bench-gate: no BENCH_METRIC rows found in the log", file=sys.stderr)
        return 2

    if args.self_test:
        # Only strictly positive throughput-style metrics sandbag
        # meaningfully (a 10x-inflated floor must trip); ratio metrics
        # that can sit at or below zero are excluded.
        positive = {n: v for n, v in metrics.items() if v > 0}
        if not positive:
            print("bench-gate SELF-TEST FAILED: no positive metrics to sandbag", file=sys.stderr)
            return 1
        # Floors sandbagged 10x up AND ceilings sandbagged 10x down:
        # every positive metric must trip once per direction.
        sandbagged = {
            "tolerance": 0.20,
            "gates": {name: value * 10.0 for name, value in positive.items()},
            "ceilings": {name: value * 0.1 for name, value in positive.items()},
        }
        failures = compare(metrics, sandbagged)
        if len(failures) != 2 * len(positive):
            print(
                "bench-gate SELF-TEST FAILED: a 10x-sandbagged baseline only "
                f"tripped {len(failures)}/{2 * len(positive)} gates",
                file=sys.stderr,
            )
            return 1
        print(
            f"bench-gate self-test OK: sandbagged baseline tripped all "
            f"{len(failures)} gates, the gate can fail"
        )
        return 0

    baseline_path = Path(args.baseline)
    baseline = json.loads(baseline_path.read_text()) if baseline_path.exists() else {}

    if args.write_baseline:
        gates = baseline.setdefault("gates", {})
        for name in list(gates) or list(metrics):
            # A null gate means "tracked, not gated" (e.g. lower-is-better
            # write-amp ratios) — refreshing must not promote it into a
            # gated throughput floor.
            if name in metrics and gates.get(name, 0) is not None:
                gates[name] = metrics[name]
        # Ceilings are never seeded from scratch (a throughput metric
        # must not silently become lower-is-better); only refresh keys
        # someone deliberately put there.
        ceilings = baseline.get("ceilings", {})
        for name in list(ceilings):
            if name in metrics and ceilings.get(name) is not None:
                ceilings[name] = metrics[name]
        baseline.setdefault("tolerance", 0.20)
        baseline_path.write_text(json.dumps(baseline, indent=2, sort_keys=True) + "\n")
        print(f"bench-gate: refreshed {baseline_path} from {len(metrics)} measured metrics")
        return 0

    failures = compare(metrics, baseline)
    summary = {
        "baseline": str(baseline_path),
        "tolerance": baseline.get("tolerance", 0.20),
        "metrics": dict(sorted(metrics.items())),
        "failures": failures,
    }
    if args.output:
        Path(args.output).write_text(json.dumps(summary, indent=2) + "\n")
        print(f"bench-gate: wrote {args.output} ({len(metrics)} metrics)")

    gated = [g for g, v in baseline.get("gates", {}).items() if v is not None]
    gated += [c for c, v in baseline.get("ceilings", {}).items() if v is not None]
    if failures:
        print("bench-gate: REGRESSIONS DETECTED", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        print(
            "  (expected? re-run scripts/bench_gate.py --write-baseline on a quiet "
            "machine and commit bench-baseline.json, or label the PR skip-bench-gate)",
            file=sys.stderr,
        )
        return 1
    print(f"bench-gate OK: {len(gated)} gated metrics within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
